//! Machine-readable run reports (`report.json`, format `MAGQRPT1`).
//!
//! One ordered-field JSON serializer ([`JsonObj`]) is shared by the run
//! reports and by `benches/sampling.rs` — BENCH_quilt.json and
//! `report.json` agree on field names by construction, so a MAGFIT-style
//! A/B comparison can join them without a translation table.
//!
//! Report kinds: `sample` (single-process run), `worker` (one dist
//! worker), `driver` (supervised dist run, embeds per-worker reports),
//! `merge` (standalone `merge-segments`). `magquilt report <file>
//! [--compare <file>]` pretty-prints and diffs them; [`validate_report`]
//! is the schema gate the tests and the CI telemetry leg run.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::{RunStats, SetupStats};
use crate::graph::{ShardMergeStats, SpillSummary};
use crate::runtime::json::Json;

/// Report format tag (the `format` field of every report.json).
pub const REPORT_FORMAT: &str = "MAGQRPT1";

/// An insertion-ordered JSON object builder: the zero-dependency
/// serializer half of [`crate::runtime::json`] (which only parses).
#[derive(Debug, Clone, Default)]
pub struct JsonObj {
    parts: Vec<(String, String)>,
}

impl JsonObj {
    /// New empty object.
    pub fn new() -> JsonObj {
        JsonObj::default()
    }

    fn push(mut self, key: &str, rendered: String) -> JsonObj {
        self.parts.push((key.to_string(), rendered));
        self
    }

    /// Unsigned integer field.
    pub fn uint(self, key: &str, v: u64) -> JsonObj {
        self.push(key, format!("{v}"))
    }

    /// Float field (3 decimals).
    pub fn float(self, key: &str, v: f64) -> JsonObj {
        self.push(key, format!("{v:.3}"))
    }

    /// String field.
    pub fn text(self, key: &str, v: &str) -> JsonObj {
        self.push(key, format!("\"{}\"", esc(v)))
    }

    /// Boolean field.
    pub fn flag(self, key: &str, v: bool) -> JsonObj {
        self.push(key, format!("{v}"))
    }

    /// Nested object field.
    pub fn obj(self, key: &str, v: JsonObj) -> JsonObj {
        let rendered = v.render();
        self.push(key, rendered)
    }

    /// Array field of pre-rendered JSON values.
    pub fn arr(self, key: &str, items: Vec<String>) -> JsonObj {
        self.push(key, format!("[{}]", items.join(",")))
    }

    /// Render compactly, fields in insertion order.
    pub fn render(&self) -> String {
        let inner: Vec<String> =
            self.parts.iter().map(|(k, v)| format!("\"{}\":{}", esc(k), v)).collect();
        format!("{{{}}}", inner.join(","))
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize [`SetupStats`] — the field names every report kind and the
/// bench `setup_sweep` section share.
pub fn setup_obj(setup: &SetupStats) -> JsonObj {
    JsonObj::new()
        .float("attrs_ms", setup.attrs_ms)
        .float("partition_ms", setup.partition_ms)
        .float("trie_ms", setup.trie_ms)
        .float("trie_merge_ms", setup.trie_merge_ms)
        .float("dag_ms", setup.dag_ms)
        .uint("setup_threads", setup.setup_threads as u64)
        .text("attr_mode", setup.attr_mode.name())
        .text("artifact_hash", &format!("{:016x}", setup.artifact_hash))
        .float("artifact_load_ms", setup.artifact_load_ms)
}

/// Serialize one [`ShardMergeStats`] row (shared with the bench
/// `shard_sweep` per-shard output).
pub fn shard_stats_obj(s: &ShardMergeStats) -> JsonObj {
    JsonObj::new()
        .uint("shard", s.shard as u64)
        .uint("edges", s.edges as u64)
        .uint("batches", s.batches)
        .uint("max_batch", s.max_batch as u64)
        .uint("duplicates_dropped", s.duplicates_dropped)
        .uint("peak_resident", s.peak_resident as u64)
        .flag("deferred", s.deferred)
        .uint("spill_runs", s.spill_runs)
        .uint("spill_bytes", s.spill_bytes)
}

/// Serialize a [`SpillSummary`].
pub fn spill_obj(spill: &SpillSummary) -> JsonObj {
    JsonObj::new()
        .uint("deferred_shards", spill.deferred_shards as u64)
        .uint("spilled_shards", spill.spilled_shards as u64)
        .uint("spill_runs", spill.spill_runs)
        .uint("spill_bytes", spill.spill_bytes)
}

/// Serialize a full [`RunStats`] (setup + spill + per-shard rows).
pub fn run_stats_obj(stats: &RunStats) -> JsonObj {
    JsonObj::new()
        .uint("partition_size", stats.partition_size as u64)
        .uint("num_jobs", stats.num_jobs as u64)
        .uint("workers", stats.workers as u64)
        .uint("num_shards", stats.num_shards as u64)
        .uint("num_edges", stats.num_edges as u64)
        .float("wall_ms", stats.wall_ms)
        .float("edges_per_sec", stats.edges_per_sec)
        .uint("dropped_resamples", stats.dropped_resamples)
        .obj("setup", setup_obj(&stats.setup))
        .obj("spill", spill_obj(&stats.spill))
        .arr(
            "shards",
            stats.shard_stats.iter().map(|s| shard_stats_obj(s).render()).collect(),
        )
}

/// The common report envelope: format tag, kind, run id, peak RSS.
pub fn report_header(kind: &str, run_id: &str) -> JsonObj {
    JsonObj::new()
        .text("format", REPORT_FORMAT)
        .text("kind", kind)
        .text("run", run_id)
        .uint("peak_rss_kb", crate::metrics::peak_rss_kb())
}

/// `kind: sample` — a single-process run.
pub fn sample_report(run_id: &str, stats: &RunStats) -> String {
    report_header("sample", run_id).obj("stats", run_stats_obj(stats)).render()
}

/// Required keys per kind, used by [`validate_report`].
fn required_keys(kind: &str) -> Option<&'static [&'static str]> {
    match kind {
        "sample" => Some(&["stats"]),
        "worker" => Some(&["worker", "jobs_run", "jobs_total", "summary", "stats"]),
        "driver" => Some(&["workers", "restarts", "merge"]),
        "merge" => Some(&["merge"]),
        _ => None,
    }
}

/// Parse and schema-check a report: the format tag, a known kind, and
/// that kind's required fields. Returns the kind.
pub fn validate_report(text: &str) -> Result<String> {
    let doc = Json::parse(text).context("report.json is not valid JSON")?;
    let format = doc.get("format").and_then(Json::as_str).unwrap_or("");
    if format != REPORT_FORMAT {
        bail!("report format {format:?} is not {REPORT_FORMAT:?}");
    }
    let kind = doc.get("kind").and_then(Json::as_str).unwrap_or("").to_string();
    let Some(required) = required_keys(&kind) else {
        bail!("unknown report kind {kind:?}");
    };
    for key in required {
        if doc.get(key).is_none() {
            bail!("report kind {kind:?} is missing required field {key:?}");
        }
    }
    if doc.get("run").and_then(Json::as_str).is_none() {
        bail!("report is missing its run id");
    }
    Ok(kind)
}

/// Pretty-print a report for `magquilt report <file>`.
pub fn pretty(text: &str) -> Result<String> {
    let doc = Json::parse(text).context("report.json is not valid JSON")?;
    let mut out = String::new();
    pretty_into(&doc, 0, &mut out);
    out.push('\n');
    Ok(out)
}

fn pretty_into(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent + 1);
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(&format!("{b}")),
        Json::Num(n) => out.push_str(&format!("{n}")),
        Json::Str(s) => out.push_str(&format!("\"{}\"", esc(s))),
        Json::Arr(items) if items.is_empty() => out.push_str("[]"),
        Json::Arr(items) => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&pad);
                pretty_into(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Json::Obj(map) if map.is_empty() => out.push_str("{}"),
        Json::Obj(map) => {
            out.push_str("{\n");
            let n = map.len();
            for (i, (k, val)) in map.iter().enumerate() {
                // lint: order-ok(sorted map)
                out.push_str(&format!("{pad}\"{}\": ", esc(k)));
                pretty_into(val, indent + 1, out);
                if i + 1 < n {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
    }
}

/// Flatten a report into dotted-path leaves for comparison.
fn flatten(v: &Json, prefix: &str, out: &mut BTreeMap<String, String>) {
    match v {
        Json::Obj(map) => {
            for (k, val) in map {
                // lint: order-ok(sorted map)
                let path = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(val, &path, out);
            }
        }
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                flatten(item, &format!("{prefix}[{i}]"), out);
            }
        }
        Json::Null => {
            out.insert(prefix.to_string(), "null".to_string());
        }
        Json::Bool(b) => {
            out.insert(prefix.to_string(), format!("{b}"));
        }
        Json::Num(n) => {
            out.insert(prefix.to_string(), format!("{n}"));
        }
        Json::Str(s) => {
            out.insert(prefix.to_string(), format!("\"{}\"", esc(s)));
        }
    }
}

/// Field-by-field diff of two reports for `magquilt report A --compare B`.
/// Numeric fields get a delta; fields present on one side only are
/// listed. Returns an empty string when the reports agree everywhere.
pub fn compare(a_text: &str, b_text: &str) -> Result<String> {
    let a = Json::parse(a_text).context("first report is not valid JSON")?;
    let b = Json::parse(b_text).context("second report is not valid JSON")?;
    let (mut fa, mut fb) = (BTreeMap::new(), BTreeMap::new());
    flatten(&a, "", &mut fa);
    flatten(&b, "", &mut fb);
    let mut out = String::new();
    for (path, va) in &fa {
        match fb.get(path) {
            None => out.push_str(&format!("- {path}: {va} (only in first)\n")),
            Some(vb) if va == vb => {}
            Some(vb) => match (va.parse::<f64>(), vb.parse::<f64>()) {
                (Ok(na), Ok(nb)) => {
                    out.push_str(&format!("~ {path}: {va} -> {vb} (delta {:+.3})\n", nb - na));
                }
                _ => out.push_str(&format!("~ {path}: {va} -> {vb}\n")),
            },
        }
    }
    for (path, vb) in &fb {
        if !fa.contains_key(path) {
            out.push_str(&format!("+ {path}: {vb} (only in second)\n"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_obj_renders_in_insertion_order() {
        let o = JsonObj::new()
            .uint("z", 1)
            .float("a", 2.5)
            .text("m", "hi \"there\"")
            .flag("ok", true)
            .obj("inner", JsonObj::new().uint("x", 7))
            .arr("items", vec!["1".to_string(), "2".to_string()]);
        assert_eq!(
            o.render(),
            r#"{"z":1,"a":2.500,"m":"hi \"there\"","ok":true,"inner":{"x":7},"items":[1,2]}"#
        );
        // And it parses back through the runtime reader.
        let j = Json::parse(&o.render()).unwrap();
        assert_eq!(j.get("inner").unwrap().get("x").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("m").unwrap().as_str(), Some("hi \"there\""));
    }

    #[test]
    fn validate_rejects_bad_reports() {
        assert!(validate_report("not json").is_err());
        assert!(validate_report(r#"{"format":"NOPE","kind":"sample"}"#).is_err());
        assert!(validate_report(r#"{"format":"MAGQRPT1","kind":"mystery","run":"r"}"#).is_err());
        // Right kind, missing required field.
        assert!(validate_report(r#"{"format":"MAGQRPT1","kind":"driver","run":"r"}"#).is_err());
        // Missing run id.
        assert!(validate_report(
            r#"{"format":"MAGQRPT1","kind":"driver","workers":2,"restarts":0,"merge":{}}"#
        )
        .is_err());
        // Minimal valid driver report.
        let ok = r#"{"format":"MAGQRPT1","kind":"driver","run":"r","workers":2,"restarts":0,"merge":{}}"#;
        assert_eq!(validate_report(ok).unwrap(), "driver");
    }

    #[test]
    fn pretty_round_trips_through_the_parser() {
        let text = r#"{"format":"MAGQRPT1","kind":"merge","run":"r","merge":{"shards":[{"shard":0,"edges":3}],"total_edges":3}}"#;
        let p = pretty(text).unwrap();
        assert!(p.contains("\"total_edges\": 3"));
        let reparsed = Json::parse(&p).unwrap();
        assert_eq!(reparsed, Json::parse(text).unwrap());
    }

    #[test]
    fn compare_reports_numeric_deltas_and_asymmetries() {
        let a = r#"{"wall_ms":10.0,"edges":100,"only_a":1,"name":"x"}"#;
        let b = r#"{"wall_ms":12.5,"edges":100,"only_b":2,"name":"y"}"#;
        let d = compare(a, b).unwrap();
        assert!(d.contains("~ wall_ms: 10 -> 12.5 (delta +2.500)"));
        assert!(d.contains("- only_a: 1 (only in first)"));
        assert!(d.contains("+ only_b: 2 (only in second)"));
        assert!(d.contains("~ name: \"x\" -> \"y\""));
        assert!(!d.contains("edges:"), "equal fields are not reported");
        // Identical reports diff to nothing.
        assert_eq!(compare(a, a).unwrap(), "");
    }
}
