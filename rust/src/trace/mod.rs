//! Structured run telemetry: versioned JSONL trace streams.
//!
//! Every run (single-process sample, distributed worker, driver, merge)
//! can emit a trace: one JSON object per line, first line a `MAGQTRC1`
//! header, then typed events (`setup`, `job_plan`, `job_done`,
//! `shard_seal`, `worker_start`, `worker_done`, `fault_armed`,
//! `worker_restarts`, `merge_shard`, `merge_done`, `run_done`) with
//! monotonic sequence numbers and run/worker ids. Files are written
//! atomically (temp + rename) via [`crate::graph::write_atomic`].
//!
//! **Telemetry is write-only.** Trace values never feed stream forks,
//! hashes, or any output-determining state — maglint invariant 7
//! (`trace-sink`, see `docs/determinism.md` and `docs/observability.md`)
//! enforces this structurally in both directions: output-determining
//! modules cannot name the trace machinery, and this module's sources
//! cannot name the RNG or hashing machinery.
//!
//! Wall-clock readings appear only in *hash-exempt* fields (`seq`,
//! `pid`, `host`, any `*_ms`); completion-order-dependent fields
//! (`disposition`, `*_bytes`, `*_runs`, `deferred`) are exempt too.
//! [`canonical_line`] strips the exempt fields, and `finish` sorts the
//! buffered events by their canonical rendering, so two same-seed runs
//! produce identical event streams after stripping — the property the
//! trace-determinism tests pin.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

pub mod console;
pub mod progress;
pub mod report;

/// Trace stream format tag (first line of every `.trace.jsonl`).
pub const TRACE_FORMAT: &str = "MAGQTRC1";

/// A typed field value attached to a trace event.
#[derive(Debug, Clone)]
pub enum Fv {
    /// Unsigned integer.
    U(u64),
    /// Float (rendered with 3 decimals).
    F(f64),
    /// String.
    S(String),
    /// Boolean.
    B(bool),
}

impl Fv {
    fn render(&self) -> String {
        match self {
            Fv::U(v) => format!("{v}"),
            Fv::F(v) => format!("{v:.3}"),
            Fv::S(v) => format!("\"{}\"", esc(v)),
            Fv::B(v) => format!("{v}"),
        }
    }
}

/// Whether a field is exempt from the determinism contract: wall-clock
/// readings, process identity, and completion-order-dependent values.
/// Everything else in a trace stream must be bit-for-bit reproducible
/// from `(model, seed, S)`.
pub fn is_exempt_field(name: &str) -> bool {
    matches!(name, "seq" | "pid" | "host" | "disposition" | "deferred" | "spilled")
        || name.ends_with("_ms")
        || name.ends_with("_bytes")
        || name.ends_with("_runs")
}

/// JSON string escaping (the subset `runtime::json` round-trips).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One buffered event: name, emission order, wall-clock offset, fields.
#[derive(Debug, Clone)]
struct Event {
    name: String,
    seq: u64,
    t_ms: f64,
    fields: Vec<(String, Fv)>,
}

/// Buffering JSONL trace writer. Events are accumulated in memory (a
/// trace is O(jobs + shards), never O(edges)) and written in one atomic
/// temp+rename at the end of the run.
#[derive(Debug)]
pub struct TraceWriter {
    run_id: String,
    kind: String,
    worker: Option<u64>,
    epoch: Instant,
    next_seq: u64,
    events: Vec<Event>,
    /// Pre-rendered event lines absorbed from child runs (the driver
    /// appends its workers' streams after its own, in worker order).
    absorbed: Vec<String>,
}

impl TraceWriter {
    /// New writer for a run. `kind` is one of `sample`, `worker`,
    /// `driver`, `merge`; `run_id` is the plan hash (or a descriptive
    /// id for plan-less runs) — it is computed by the caller, never
    /// here.
    pub fn new(run_id: &str, kind: &str, worker: Option<usize>) -> TraceWriter {
        TraceWriter {
            run_id: run_id.to_string(),
            kind: kind.to_string(),
            worker: worker.map(|w| w as u64),
            epoch: Instant::now(),
            next_seq: 0,
            events: Vec::new(),
            absorbed: Vec::new(),
        }
    }

    /// Record one event. `seq` and `t_ms` are assigned here, at emission
    /// (real order); both are exempt fields, and `finish_lines` later
    /// sorts by canonical (non-exempt) content so thread interleaving
    /// never shows in the stripped stream.
    pub fn emit(&mut self, name: &str, fields: &[(&str, Fv)]) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.events.push(Event {
            name: name.to_string(),
            seq,
            t_ms: self.epoch.elapsed().as_secs_f64() * 1e3,
            fields: fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        });
    }

    /// Append pre-rendered event lines from a child stream.
    pub fn absorb(&mut self, lines: impl IntoIterator<Item = String>) {
        self.absorbed.extend(lines);
    }

    /// The stream header line.
    pub fn header_line(&self) -> String {
        let mut s = format!(
            "{{\"format\":\"{TRACE_FORMAT}\",\"run\":\"{}\",\"kind\":\"{}\"",
            esc(&self.run_id),
            esc(&self.kind),
        );
        if let Some(w) = self.worker {
            s.push_str(&format!(",\"worker\":{w}"));
        }
        s.push_str(&format!(",\"pid\":{}}}", std::process::id()));
        s
    }

    fn render_event(&self, e: &Event) -> String {
        let mut s = format!("{{\"event\":\"{}\"", esc(&e.name));
        if let Some(w) = self.worker {
            s.push_str(&format!(",\"worker\":{w}"));
        }
        for (k, v) in &e.fields {
            s.push_str(&format!(",\"{}\":{}", esc(k), v.render()));
        }
        s.push_str(&format!(",\"seq\":{},\"t_ms\":{:.3}}}", e.seq, e.t_ms));
        s
    }

    /// The canonical (sort) key of an event: its name plus every
    /// non-exempt field, in emission field order.
    fn canonical_key(&self, e: &Event) -> String {
        let mut s = e.name.clone();
        for (k, v) in &e.fields {
            if !is_exempt_field(k) {
                s.push_str(&format!("|{k}={}", v.render()));
            }
        }
        s
    }

    /// Finalize: header, then events stable-sorted by canonical key,
    /// then absorbed child streams verbatim.
    pub fn finish_lines(&self) -> Vec<String> {
        let mut keyed: Vec<(String, String)> = self
            .events
            .iter()
            .map(|e| (self.canonical_key(e), self.render_event(e)))
            .collect();
        keyed.sort_by(|a, b| a.0.cmp(&b.0)); // stable: ties keep emission order
        let mut out = Vec::with_capacity(1 + keyed.len() + self.absorbed.len());
        out.push(self.header_line());
        out.extend(keyed.into_iter().map(|(_, line)| line));
        out.extend(self.absorbed.iter().cloned());
        out
    }
}

/// Cheap-clone handle threaded through the coordinator, sinks, and the
/// distributed runtime. Disabled (the default) it is a no-op with no
/// allocation per event — pay-for-what-you-use.
#[derive(Debug, Clone, Default)]
pub struct TraceHandle(Option<Arc<Mutex<TraceWriter>>>);

impl TraceHandle {
    /// The no-op handle.
    pub fn disabled() -> TraceHandle {
        TraceHandle(None)
    }

    /// An enabled handle for one run.
    pub fn new(run_id: &str, kind: &str, worker: Option<usize>) -> TraceHandle {
        TraceHandle(Some(Arc::new(Mutex::new(TraceWriter::new(run_id, kind, worker)))))
    }

    /// Whether events are being recorded.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    fn with<T>(&self, f: impl FnOnce(&mut TraceWriter) -> T) -> Option<T> {
        let cell = self.0.as_ref()?;
        // A panicked emitter cannot corrupt a buffer of rendered lines;
        // recover the poisoned lock rather than cascading the panic.
        let mut w = match cell.lock() {
            Ok(w) => w,
            Err(poisoned) => poisoned.into_inner(),
        };
        Some(f(&mut w))
    }

    /// Record one event (no-op when disabled).
    pub fn emit(&self, name: &str, fields: &[(&str, Fv)]) {
        self.with(|w| w.emit(name, fields));
    }

    /// Append a child run's rendered stream (its header line removed).
    pub fn absorb_stream(&self, text: &str) {
        self.with(|w| {
            w.absorb(
                text.lines()
                    .skip(1) // the child's header
                    .filter(|l| !l.trim().is_empty())
                    .map(|l| l.to_string()),
            );
        });
    }

    /// The finalized stream (for tests and for the driver's absorption
    /// of worker streams). Empty when disabled.
    pub fn lines(&self) -> Vec<String> {
        self.with(|w| w.finish_lines()).unwrap_or_default()
    }

    /// Atomically write the finalized stream to `path` (no-op when
    /// disabled).
    pub fn write_to(&self, path: &Path) -> Result<()> {
        let Some(lines) = self.with(|w| w.finish_lines()) else {
            return Ok(());
        };
        let mut body = lines.join("\n");
        body.push('\n');
        let (dir, name) = split_dir_name(path)
            .with_context(|| format!("trace path {} has no file name", path.display()))?;
        crate::graph::write_atomic(&dir, &name, body.as_bytes())
            .with_context(|| format!("writing trace stream {}", path.display()))
    }
}

/// Split a path into (parent dir, file name) for `write_atomic`.
pub(crate) fn split_dir_name(path: &Path) -> Option<(std::path::PathBuf, String)> {
    let name = path.file_name()?.to_string_lossy().into_owned();
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    Some((dir, name))
}

/// Canonicalize one rendered trace line for determinism comparison:
/// parse it, drop the exempt fields, and re-render with sorted keys.
/// Returns `None` for non-JSON lines.
pub fn canonical_line(line: &str) -> Option<String> {
    let parsed = crate::runtime::json::Json::parse(line).ok()?;
    let crate::runtime::json::Json::Obj(map) = parsed else {
        return None;
    };
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in &map {
        // BTreeMap iteration is sorted by key — deterministic. lint: order-ok(sorted map)
        if is_exempt_field(k) {
            continue;
        }
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!("\"{}\":{}", esc(k), render_json(v)));
    }
    out.push('}');
    Some(out)
}

fn render_json(v: &crate::runtime::json::Json) -> String {
    use crate::runtime::json::Json;
    match v {
        Json::Null => "null".to_string(),
        Json::Bool(b) => format!("{b}"),
        Json::Num(n) => format!("{n}"),
        Json::Str(s) => format!("\"{}\"", esc(s)),
        Json::Arr(items) => {
            let inner: Vec<String> = items.iter().map(render_json).collect();
            format!("[{}]", inner.join(","))
        }
        Json::Obj(map) => {
            let inner: Vec<String> = map
                .iter() // lint: order-ok(sorted map)
                .map(|(k, v)| format!("\"{}\":{}", esc(k), render_json(v)))
                .collect();
            format!("{{{}}}", inner.join(","))
        }
    }
}

/// Strip the exempt fields from a whole rendered stream — the
/// comparison form used by the trace-determinism tests.
pub fn canonical_stream(lines: &[String]) -> Vec<String> {
    lines.iter().filter_map(|l| canonical_line(l)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_a_noop() {
        let t = TraceHandle::disabled();
        assert!(!t.is_enabled());
        t.emit("setup", &[("setup_threads", Fv::U(4))]);
        assert!(t.lines().is_empty());
        assert!(t.write_to(Path::new("/nonexistent/dir/x.trace.jsonl")).is_ok());
    }

    #[test]
    fn header_and_events_render_as_json() {
        let t = TraceHandle::new("00ff00ff00ff00ff", "worker", Some(3));
        t.emit("shard_seal", &[("shard", Fv::U(2)), ("edges", Fv::U(17))]);
        t.emit("note", &[("msg", Fv::S("a \"quoted\"\npath".into()))]);
        let lines = t.lines();
        assert_eq!(lines.len(), 3);
        let header = crate::runtime::json::Json::parse(&lines[0]).unwrap();
        assert_eq!(header.get("format").unwrap().as_str(), Some(TRACE_FORMAT));
        assert_eq!(header.get("run").unwrap().as_str(), Some("00ff00ff00ff00ff"));
        assert_eq!(header.get("kind").unwrap().as_str(), Some("worker"));
        assert_eq!(header.get("worker").unwrap().as_u64(), Some(3));
        for line in &lines[1..] {
            let e = crate::runtime::json::Json::parse(line).unwrap();
            assert!(e.get("event").is_some());
            assert!(e.get("seq").is_some());
            assert!(e.get("t_ms").is_some());
            assert_eq!(e.get("worker").unwrap().as_u64(), Some(3));
        }
        let note = crate::runtime::json::Json::parse(&lines[2]).unwrap();
        assert_eq!(note.get("msg").unwrap().as_str(), Some("a \"quoted\"\npath"));
    }

    #[test]
    fn seq_is_monotonic_in_emission_order() {
        let t = TraceHandle::new("r", "sample", None);
        for i in 0..5u64 {
            t.emit("job_done", &[("job", Fv::U(i))]);
        }
        let mut seqs: Vec<u64> = t.lines()[1..]
            .iter()
            .map(|l| {
                crate::runtime::json::Json::parse(l).unwrap().get("seq").unwrap().as_u64().unwrap()
            })
            .collect();
        seqs.sort_unstable();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn canonical_sort_neutralizes_emission_order() {
        // The same logical events emitted in two different thread
        // interleavings produce identical streams after stripping the
        // exempt fields — the trace-determinism contract.
        let a = TraceHandle::new("run", "sample", None);
        a.emit("shard_seal", &[("shard", Fv::U(0)), ("edges", Fv::U(10))]);
        a.emit("shard_seal", &[("shard", Fv::U(1)), ("edges", Fv::U(20))]);
        a.emit("run_done", &[("edges", Fv::U(30)), ("wall_ms", Fv::F(1.5))]);
        let b = TraceHandle::new("run", "sample", None);
        b.emit("shard_seal", &[("shard", Fv::U(1)), ("edges", Fv::U(20))]);
        b.emit("run_done", &[("edges", Fv::U(30)), ("wall_ms", Fv::F(99.0))]);
        b.emit("shard_seal", &[("shard", Fv::U(0)), ("edges", Fv::U(10))]);
        assert_eq!(canonical_stream(&a.lines()), canonical_stream(&b.lines()));
    }

    #[test]
    fn exempt_fields_are_stripped_by_canonical_line() {
        let line = r#"{"event":"shard_seal","shard":1,"edges":9,"disposition":"spilled","spill_bytes":64,"seq":4,"t_ms":0.120}"#;
        let canon = canonical_line(line).unwrap();
        assert_eq!(canon, r#"{"edges":9,"event":"shard_seal","shard":1}"#);
        assert!(is_exempt_field("t_ms"));
        assert!(is_exempt_field("artifact_load_ms"));
        assert!(is_exempt_field("spill_bytes"));
        assert!(is_exempt_field("seq"));
        assert!(!is_exempt_field("edges"));
        assert!(!is_exempt_field("shard"));
        assert!(!is_exempt_field("seed"));
    }

    #[test]
    fn absorbed_child_streams_append_after_own_events() {
        let worker = TraceHandle::new("p", "worker", Some(1));
        worker.emit("worker_done", &[("owned_edges", Fv::U(7))]);
        let child_text = format!("{}\n", worker.lines().join("\n"));
        let driver = TraceHandle::new("p", "driver", None);
        driver.emit("worker_restarts", &[("restarts", Fv::U(0))]);
        driver.absorb_stream(&child_text);
        let lines = driver.lines();
        assert_eq!(lines.len(), 3); // header + own event + child event
        assert!(lines[1].contains("\"event\":\"worker_restarts\""));
        assert!(lines[2].contains("\"event\":\"worker_done\""));
        assert!(!lines[2].contains("\"format\""), "child header must be dropped");
    }

    #[test]
    fn write_to_lands_a_parseable_stream() {
        let dir = std::env::temp_dir().join("magquilt_trace_write");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let t = TraceHandle::new("deadbeefdeadbeef", "merge", None);
        t.emit("merge_done", &[("total_edges", Fv::U(123)), ("merge_ms", Fv::F(4.25))]);
        let path = dir.join("run.trace.jsonl");
        t.write_to(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            crate::runtime::json::Json::parse(line).unwrap();
        }
        assert!(lines[0].contains("\"format\":\"MAGQTRC1\""));
        // No temp residue next to the stream.
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["run.trace.jsonl".to_string()]);
    }
}
