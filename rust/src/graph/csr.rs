//! Compressed sparse row adjacency for the analysis algorithms.

use super::{EdgeList, NodeId};

/// CSR adjacency: `offsets[i]..offsets[i+1]` indexes `targets` with the
/// out-neighbors of node `i` (sorted, deduplicated).
#[derive(Debug, Clone)]
pub struct Csr {
    offsets: Vec<usize>,
    targets: Vec<NodeId>,
}

impl Csr {
    /// Build from an edge list. Duplicates are removed; order of input does
    /// not matter. O(|V| + |E| log deg) via per-row sort.
    pub fn from_edge_list(g: &EdgeList) -> Self {
        let n = g.num_nodes();
        let mut counts = vec![0usize; n + 1];
        for &(s, _) in g.edges() {
            counts[s as usize + 1] += 1;
        }
        let mut offsets = counts;
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut targets = vec![0 as NodeId; g.num_edges()];
        let mut cursor = offsets.clone();
        for &(s, t) in g.edges() {
            targets[cursor[s as usize]] = t;
            cursor[s as usize] += 1;
        }
        // Sort + dedup each row, compacting in place.
        let mut write = 0usize;
        let mut new_offsets = vec![0usize; n + 1];
        for i in 0..n {
            let (start, end) = (offsets[i], offsets[i + 1]);
            let row = &mut targets[start..end];
            row.sort_unstable();
            let mut prev: Option<NodeId> = None;
            let row_start = write;
            for k in start..end {
                let t = targets[k];
                if prev != Some(t) {
                    targets[write] = t;
                    write += 1;
                    prev = Some(t);
                }
            }
            new_offsets[i] = row_start;
        }
        new_offsets[n] = write;
        // new_offsets currently stores row starts; it is already monotone.
        targets.truncate(write);
        Csr { offsets: new_offsets, targets }
    }

    /// Transpose (reverse all edges).
    pub fn transpose(&self) -> Csr {
        let n = self.num_nodes();
        let mut counts = vec![0usize; n + 1];
        for &t in &self.targets {
            counts[t as usize + 1] += 1;
        }
        let mut offsets = counts;
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut targets = vec![0 as NodeId; self.targets.len()];
        let mut cursor = offsets.clone();
        for s in 0..n {
            for &t in self.neighbors(s as NodeId) {
                targets[cursor[t as usize]] = s as NodeId;
                cursor[t as usize] += 1;
            }
        }
        // rows come out sorted because source ids ascend.
        Csr { offsets, targets }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of stored (deduplicated) edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Out-neighbors of `v` (sorted).
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> &[NodeId] {
        &self.targets[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: NodeId) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Whether the edge (s, t) exists — binary search, O(log deg).
    #[inline]
    pub fn has_edge(&self, s: NodeId, t: NodeId) -> bool {
        self.neighbors(s).binary_search(&t).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        let g = EdgeList::from_edges(4, vec![(0, 2), (0, 1), (1, 3), (0, 1), (3, 0)]);
        Csr::from_edge_list(&g)
    }

    #[test]
    fn rows_sorted_dedup() {
        let c = sample();
        assert_eq!(c.neighbors(0), &[1, 2]);
        assert_eq!(c.neighbors(1), &[3]);
        assert_eq!(c.neighbors(2), &[] as &[NodeId]);
        assert_eq!(c.neighbors(3), &[0]);
        assert_eq!(c.num_edges(), 4); // one duplicate removed
    }

    #[test]
    fn has_edge() {
        let c = sample();
        assert!(c.has_edge(0, 2));
        assert!(!c.has_edge(2, 0));
    }

    #[test]
    fn transpose_roundtrip() {
        let c = sample();
        let t = c.transpose();
        assert_eq!(t.neighbors(1), &[0]);
        assert_eq!(t.neighbors(0), &[3]);
        let back = t.transpose();
        for v in 0..4 {
            assert_eq!(back.neighbors(v as NodeId), c.neighbors(v as NodeId));
        }
    }

    #[test]
    fn empty_graph() {
        let c = Csr::from_edge_list(&EdgeList::new(3));
        assert_eq!(c.num_nodes(), 3);
        assert_eq!(c.num_edges(), 0);
        assert_eq!(c.degree(1), 0);
    }
}
