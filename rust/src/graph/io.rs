//! Edge-list IO: whitespace text (SNAP-compatible) and a compact binary
//! format for large samples.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::{Edge, EdgeList};

/// Magic bytes opening every `MAGQEDG1` file — public so callers (the
/// CLI's format sniffing) can recognize the format without relying on
/// file extensions.
pub const BINARY_MAGIC: &[u8; 8] = b"MAGQEDG1";
/// Header bytes: magic (8) + n (u64) + m (u64).
const BINARY_HEADER_LEN: u64 = 24;
/// Byte offset of the edge count in the header (for back-patching).
const BINARY_EDGE_COUNT_OFFSET: u64 = 16;
/// Bytes per stored edge: two little-endian u32s.
pub(super) const BINARY_EDGE_LEN: u64 = 8;

/// Write edges in the `MAGQEDG1` record layout (consecutive `(src, dst)`
/// pairs of little-endian u32s). The single encoder for the format:
/// both the binary file body and spill runs go through here, so the
/// layout cannot drift between them.
pub(super) fn write_edge_records(w: &mut impl Write, edges: &[Edge]) -> io::Result<()> {
    for &(s, t) in edges {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}
/// Largest node count accepted from an (untrusted) binary header:
/// `ModelSpec` caps models at 2^31 nodes, so anything larger is corrupt.
const MAX_BINARY_NODES: u64 = 1 << 31;
/// Edges decoded per read when streaming a binary body (1 MiB buffers) —
/// the record loop issues one large `read_exact` per chunk instead of
/// two 4-byte reads per edge.
const READ_CHUNK_EDGES: usize = 128 * 1024;

/// Incremental writer for the `MAGQEDG1` binary format, used by
/// [`super::BinaryFileSink`] to stream sorted shards to disk without ever
/// holding the whole edge list. The header's edge count is written as a
/// `u64::MAX` placeholder and back-patched by
/// [`BinaryEdgeWriter::finalize`] — a run that dies mid-stream leaves a
/// file whose claimed count exceeds the file size, so
/// [`read_edge_list_binary`] rejects the partial output instead of
/// parsing it as a valid (empty or truncated) graph.
#[derive(Debug)]
pub struct BinaryEdgeWriter {
    writer: BufWriter<File>,
}

impl BinaryEdgeWriter {
    /// Create/truncate `path` and write the header with the placeholder
    /// edge count.
    pub fn create(path: &Path, num_nodes: usize) -> io::Result<Self> {
        let mut writer = BufWriter::new(File::create(path)?);
        writer.write_all(BINARY_MAGIC)?;
        writer.write_all(&(num_nodes as u64).to_le_bytes())?;
        writer.write_all(&u64::MAX.to_le_bytes())?;
        Ok(BinaryEdgeWriter { writer })
    }

    /// Append a run of edges.
    pub fn write_edges(&mut self, edges: &[Edge]) -> io::Result<()> {
        write_edge_records(&mut self.writer, edges)
    }

    /// Flush and back-patch the header with the true edge count.
    ///
    /// Ordering matters: the edge records are flushed **and synced**
    /// before the placeholder count is overwritten, and the patch is
    /// synced again. The patched count is what makes the file pass
    /// [`read_edge_list_binary`] validation, so it must never become
    /// durable ahead of the data it vouches for — a crash with the old
    /// patch-then-sync order could persist the count while trailing
    /// records were still in the page cache, leaving a short-but-valid
    /// file. With this order a crash at any point leaves either the
    /// `u64::MAX` placeholder (rejected by the size check) or a fully
    /// synced file.
    pub fn finalize(self, num_edges: u64) -> io::Result<()> {
        let mut file = self.writer.into_inner().map_err(|e| e.into_error())?;
        file.sync_all()?;
        file.seek(SeekFrom::Start(BINARY_EDGE_COUNT_OFFSET))?;
        file.write_all(&num_edges.to_le_bytes())?;
        file.sync_all()
    }
}

/// Write `src<TAB>dst` lines with a `# nodes=N edges=M` header.
pub fn write_edge_list_text(g: &EdgeList, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# nodes={} edges={}", g.num_nodes(), g.num_edges())?;
    for &(s, t) in g.edges() {
        writeln!(w, "{s}\t{t}")?;
    }
    w.flush()
}

/// Read the text format. Lines starting with `#` are comments; the
/// `nodes=` header is honored if present, otherwise n = max id + 1.
pub fn read_edge_list_text(path: &Path) -> io::Result<EdgeList> {
    let r = BufReader::new(File::open(path)?);
    let mut edges = Vec::new();
    let mut n_hint: Option<usize> = None;
    for line in r.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            for tok in rest.split_whitespace() {
                if let Some(v) = tok.strip_prefix("nodes=") {
                    n_hint = v.parse().ok();
                }
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad line: {line}")));
        };
        let s: u32 = a
            .parse()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e}: {line}")))?;
        let t: u32 = b
            .parse()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e}: {line}")))?;
        edges.push((s, t));
    }
    let max_id = edges.iter().map(|&(s, t)| s.max(t)).max().map(|m| m as usize + 1).unwrap_or(0);
    let n = n_hint.unwrap_or(max_id).max(max_id);
    Ok(EdgeList::from_edges(n, edges))
}

/// Binary format: magic, u64 n, u64 m, then m (u32, u32) pairs, LE.
pub fn write_edge_list_binary(g: &EdgeList, path: &Path) -> io::Result<()> {
    let mut w = BinaryEdgeWriter::create(path, g.num_nodes())?;
    w.write_edges(g.edges())?;
    w.finalize(g.num_edges() as u64)
}

/// The validated header of a `MAGQEDG1` file: node and edge counts whose
/// invariants (magic, node-count cap, edge count vs file size) have
/// already been checked against the file they came from.
///
/// Produced by [`read_binary_header`]; carrying it to [`read_binary_body`]
/// lets a caller validate a directory of files in one scan pass and read
/// the bodies later without re-opening or re-validating any header — the
/// distributed merge's single-streaming-pass contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinaryHeader {
    /// Node count n from the header.
    pub num_nodes: u64,
    /// Edge count m from the header (validated against the file size).
    pub num_edges: u64,
}

/// Validate the 24-byte header of an open file against its length.
fn read_header(r: &mut impl Read, file_len: u64) -> io::Result<BinaryHeader> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BINARY_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8);
    r.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8);
    // Files written by this tool never exceed ModelSpec's log2_nodes <= 31;
    // beyond that the header is corrupt (and an unchecked n would drive
    // O(n) allocations in every downstream consumer).
    if n > MAX_BINARY_NODES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("node count {n} exceeds the supported maximum {MAX_BINARY_NODES}"),
        ));
    }
    let max_edges = file_len.saturating_sub(BINARY_HEADER_LEN) / BINARY_EDGE_LEN;
    if m > max_edges {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("header claims {m} edges but the file has room for {max_edges}"),
        ));
    }
    Ok(BinaryHeader { num_nodes: n, num_edges: m })
}

/// Decode `m` records from `r` in [`READ_CHUNK_EDGES`]-sized chunks,
/// validating every id against `n`. A short read surfaces as
/// `InvalidData` (the count was vouched for by a validated header, so
/// missing records mean the file was truncated under us).
fn read_records_chunked(r: &mut impl Read, n: u64, m: u64) -> io::Result<Vec<Edge>> {
    let mut edges: Vec<Edge> = Vec::with_capacity(m as usize);
    let mut bytes = vec![0u8; READ_CHUNK_EDGES.min(m as usize).max(1) * BINARY_EDGE_LEN as usize];
    let mut remaining = m;
    while remaining > 0 {
        let take = remaining.min(READ_CHUNK_EDGES as u64) as usize;
        let buf = &mut bytes[..take * BINARY_EDGE_LEN as usize];
        r.read_exact(buf).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("edge records truncated: {e}"))
        })?;
        for rec in buf.chunks_exact(BINARY_EDGE_LEN as usize) {
            let s = u32::from_le_bytes(rec[..4].try_into().expect("4-byte slice")); // lint: panic-ok(chunks_exact(8) guarantees the width)
            let t = u32::from_le_bytes(rec[4..].try_into().expect("4-byte slice")); // lint: panic-ok(chunks_exact(8) guarantees the width)
            if u64::from(s) >= n || u64::from(t) >= n {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("edge ({s}, {t}) out of bounds for n = {n}"),
                ));
            }
            edges.push((s, t));
        }
        remaining -= take as u64;
    }
    Ok(edges)
}

/// Open `path` and validate its `MAGQEDG1` header without touching the
/// body: magic bytes, node-count cap, and the claimed edge count against
/// the actual file size. One cheap (24-byte) read per file — the scan
/// pass of a scan-then-merge pipeline.
pub fn read_binary_header(path: &Path) -> io::Result<BinaryHeader> {
    let mut file = File::open(path)?;
    let file_len = file.metadata()?.len();
    read_header(&mut file, file_len)
}

/// Read the body of a file whose header was already validated by
/// [`read_binary_header`], skipping the header bytes and streaming the
/// records in large chunks. Ids are still validated against
/// `header.num_nodes` and a file truncated since the scan surfaces as
/// `InvalidData`, so a stale header cannot smuggle bad data through.
pub fn read_binary_body(path: &Path, header: &BinaryHeader) -> io::Result<Vec<Edge>> {
    let mut file = File::open(path)?;
    file.seek(SeekFrom::Start(BINARY_HEADER_LEN))?;
    read_records_chunked(&mut file, header.num_nodes, header.num_edges)
}

/// Read the binary format.
///
/// The header is untrusted input: the claimed edge count is checked
/// against the actual file size before any allocation (a 24-byte corrupt
/// file must not trigger a multi-GB `Vec::with_capacity`), and every edge
/// id is validated against `n` before the list is returned — also in
/// release builds, where `EdgeList::from_edges` only debug-asserts.
pub fn read_edge_list_binary(path: &Path) -> io::Result<EdgeList> {
    let mut file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let header = read_header(&mut file, file_len)?;
    let edges = read_records_chunked(&mut file, header.num_nodes, header.num_edges)?;
    Ok(EdgeList::from_edges(header.num_nodes as usize, edges))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EdgeList {
        EdgeList::from_edges(5, vec![(0, 1), (3, 4), (2, 2)])
    }

    #[test]
    fn text_roundtrip() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let g = sample();
        write_edge_list_text(&g, &p).unwrap();
        let back = read_edge_list_text(&p).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        let g = sample();
        write_edge_list_binary(&g, &p).unwrap();
        let back = read_edge_list_binary(&p).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn text_without_header_infers_n() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("noheader.txt");
        std::fs::write(&p, "0 3\n1 2\n").unwrap();
        let g = read_edge_list_text(&p).unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn binary_oversized_header_count_rejected_without_allocation() {
        // A tiny file whose header claims u64::MAX edges must be rejected
        // up front (the old code passed the count to Vec::with_capacity).
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("corrupt_count.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BINARY_MAGIC);
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = read_edge_list_binary(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn binary_out_of_bounds_edge_rejected() {
        // Edge ids >= n must be an error in release builds too (from_edges
        // only debug-asserts).
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("corrupt_edge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BINARY_MAGIC);
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&5u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = read_edge_list_binary(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn binary_absurd_node_count_rejected() {
        // A 24-byte corrupt header must not drive O(n) allocations in
        // downstream consumers (degree vectors, CSR offsets).
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, n) in [("corrupt_nodes_max.bin", u64::MAX), ("corrupt_nodes_33.bin", 1 << 33)]
        {
            let p = dir.join(name);
            let mut bytes = Vec::new();
            bytes.extend_from_slice(BINARY_MAGIC);
            bytes.extend_from_slice(&n.to_le_bytes());
            bytes.extend_from_slice(&0u64.to_le_bytes());
            std::fs::write(&p, &bytes).unwrap();
            let err = read_edge_list_binary(&p).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "n = {n}");
        }
        // The cap itself is fine: an empty graph at the maximum size reads.
        let p = dir.join("max_nodes_ok.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BINARY_MAGIC);
        bytes.extend_from_slice(&MAX_BINARY_NODES.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let g = read_edge_list_binary(&p).unwrap();
        assert_eq!(g.num_nodes(), MAX_BINARY_NODES as usize);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn binary_writer_streams_and_patches_count() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("streamed.bin");
        let mut w = BinaryEdgeWriter::create(&p, 4).unwrap();
        w.write_edges(&[(0, 1)]).unwrap();
        w.write_edges(&[(2, 3), (3, 0)]).unwrap();
        w.finalize(3).unwrap();
        let g = read_edge_list_binary(&p).unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.edges(), &[(0, 1), (2, 3), (3, 0)]);
    }

    #[test]
    fn binary_writer_unfinalized_file_is_rejected() {
        // A run that dies before finalize (crash, disk full) must not
        // leave a file that parses as a valid graph: the placeholder
        // count fails the size check.
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("unfinalized.bin");
        let mut w = BinaryEdgeWriter::create(&p, 4).unwrap();
        w.write_edges(&[(0, 1), (2, 3)]).unwrap();
        drop(w); // BufWriter flushes on drop; finalize never runs
        let err = read_edge_list_binary(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn finalize_crash_points_never_yield_a_valid_partial_file() {
        // Simulate the on-disk image at each crash point of the
        // write-stream-finalize sequence and assert only the fully
        // finalized image validates. The dangerous point is (c): with the
        // count patched but records missing, the size check is the only
        // defense — which is why finalize syncs data before patching.
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let edges: [Edge; 3] = [(0, 1), (1, 2), (2, 0)];
        let mut full = Vec::new();
        full.extend_from_slice(BINARY_MAGIC);
        full.extend_from_slice(&3u64.to_le_bytes());
        full.extend_from_slice(&u64::MAX.to_le_bytes());
        for &(s, t) in &edges {
            full.extend_from_slice(&s.to_le_bytes());
            full.extend_from_slice(&t.to_le_bytes());
        }

        // (a) Crash after the header, before any record: placeholder
        // count, no data.
        let p = dir.join("crash_header_only.bin");
        std::fs::write(&p, &full[..BINARY_HEADER_LEN as usize]).unwrap();
        assert!(read_edge_list_binary(&p).is_err());

        // (b) Crash after all records, before the back-patch: the
        // placeholder still exceeds the file size.
        let p = dir.join("crash_before_patch.bin");
        std::fs::write(&p, &full).unwrap();
        assert!(read_edge_list_binary(&p).is_err());

        // (c) Count patched but the tail record lost (the partial-write
        // scenario the sync-before-patch order prevents): claimed count
        // exceeds what the file holds, so validation rejects it.
        let mut patched = full.clone();
        patched[BINARY_EDGE_COUNT_OFFSET as usize..BINARY_HEADER_LEN as usize]
            .copy_from_slice(&(edges.len() as u64).to_le_bytes());
        let p = dir.join("crash_truncated_records.bin");
        std::fs::write(&p, &patched[..patched.len() - BINARY_EDGE_LEN as usize]).unwrap();
        assert!(read_edge_list_binary(&p).is_err());

        // (d) The fully finalized image reads back exactly.
        let p = dir.join("finalized_ok.bin");
        std::fs::write(&p, &patched).unwrap();
        let g = read_edge_list_binary(&p).unwrap();
        assert_eq!(g.edges(), &edges);
    }

    #[test]
    fn header_body_split_matches_whole_file_read() {
        // The scan-then-merge path: validate the header once, read the
        // body later — must see exactly what read_edge_list_binary sees.
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("split.bin");
        let g = sample();
        write_edge_list_binary(&g, &p).unwrap();
        let h = read_binary_header(&p).unwrap();
        assert_eq!(h, BinaryHeader { num_nodes: 5, num_edges: 3 });
        let body = read_binary_body(&p, &h).unwrap();
        assert_eq!(body, g.edges());
    }

    #[test]
    fn body_read_rejects_truncation_after_header_scan() {
        // A file that shrinks between the scan pass and the body read
        // must fail loud, not deliver fewer edges.
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shrunk.bin");
        let g = sample();
        write_edge_list_binary(&g, &p).unwrap();
        let h = read_binary_header(&p).unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(BINARY_HEADER_LEN + BINARY_EDGE_LEN).unwrap();
        drop(f);
        let err = read_binary_body(&p, &h).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn chunked_reader_crosses_chunk_boundaries() {
        // More edges than one decode chunk: the large-read loop must
        // reassemble records exactly across chunk seams.
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("many.bin");
        let m = READ_CHUNK_EDGES + 17;
        let edges: Vec<Edge> = (0..m as u32).map(|i| (i, i.wrapping_mul(31) % m as u32)).collect();
        let g = EdgeList::from_edges(m, edges);
        write_edge_list_binary(&g, &p).unwrap();
        assert_eq!(read_edge_list_binary(&p).unwrap(), g);
    }

    #[test]
    fn text_bad_line_errors() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_edge_list_text(&p).is_err());
    }
}
