//! Edge-list IO: whitespace text (SNAP-compatible) and a compact binary
//! format for large samples.

use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::EdgeList;

const BINARY_MAGIC: &[u8; 8] = b"MAGQEDG1";

/// Write `src<TAB>dst` lines with a `# nodes=N edges=M` header.
pub fn write_edge_list_text(g: &EdgeList, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# nodes={} edges={}", g.num_nodes(), g.num_edges())?;
    for &(s, t) in g.edges() {
        writeln!(w, "{s}\t{t}")?;
    }
    w.flush()
}

/// Read the text format. Lines starting with `#` are comments; the
/// `nodes=` header is honored if present, otherwise n = max id + 1.
pub fn read_edge_list_text(path: &Path) -> io::Result<EdgeList> {
    let r = BufReader::new(File::open(path)?);
    let mut edges = Vec::new();
    let mut n_hint: Option<usize> = None;
    for line in r.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            for tok in rest.split_whitespace() {
                if let Some(v) = tok.strip_prefix("nodes=") {
                    n_hint = v.parse().ok();
                }
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad line: {line}")));
        };
        let s: u32 = a
            .parse()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e}: {line}")))?;
        let t: u32 = b
            .parse()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e}: {line}")))?;
        edges.push((s, t));
    }
    let max_id = edges.iter().map(|&(s, t)| s.max(t)).max().map(|m| m as usize + 1).unwrap_or(0);
    let n = n_hint.unwrap_or(max_id).max(max_id);
    Ok(EdgeList::from_edges(n, edges))
}

/// Binary format: magic, u64 n, u64 m, then m (u32, u32) pairs, LE.
pub fn write_edge_list_binary(g: &EdgeList, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BINARY_MAGIC)?;
    w.write_all(&(g.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    for &(s, t) in g.edges() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()
}

/// Read the binary format.
pub fn read_edge_list_binary(path: &Path) -> io::Result<EdgeList> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BINARY_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut edges = Vec::with_capacity(m);
    let mut buf4 = [0u8; 4];
    for _ in 0..m {
        r.read_exact(&mut buf4)?;
        let s = u32::from_le_bytes(buf4);
        r.read_exact(&mut buf4)?;
        let t = u32::from_le_bytes(buf4);
        edges.push((s, t));
    }
    Ok(EdgeList::from_edges(n, edges))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EdgeList {
        EdgeList::from_edges(5, vec![(0, 1), (3, 4), (2, 2)])
    }

    #[test]
    fn text_roundtrip() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let g = sample();
        write_edge_list_text(&g, &p).unwrap();
        let back = read_edge_list_text(&p).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        let g = sample();
        write_edge_list_binary(&g, &p).unwrap();
        let back = read_edge_list_binary(&p).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn text_without_header_infers_n() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("noheader.txt");
        std::fs::write(&p, "0 3\n1 2\n").unwrap();
        let g = read_edge_list_text(&p).unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn text_bad_line_errors() {
        let dir = std::env::temp_dir().join("magquilt_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_edge_list_text(&p).is_err());
    }
}
