//! Temp-file spill runs for out-of-order shard delivery.
//!
//! When a shard finishes before its slot in the output file is reachable
//! (an earlier shard is still merging) and the sink's in-memory budget is
//! exhausted, the shard's sorted run is *spilled*: streamed to a private
//! temp file and read back — in bounded chunks — once the file frontier
//! catches up. [`SpillWriter`] writes a run, [`SpillRun`] reads it back
//! and deletes the file when dropped.
//!
//! The on-disk layout is the `MAGQEDG1` **record** format — consecutive
//! `(src, dst)` pairs of little-endian `u32`s, 8 bytes per edge — with no
//! header: a spill file is private to the process that wrote it, its edge
//! count lives in the in-memory [`SpillRun`], and keeping the records
//! header-free lets the drain loop concatenate them into the final binary
//! file without any translation.

use std::fs::File;
use std::io::{self, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::io::{write_edge_records, BINARY_EDGE_LEN};
use super::Edge;

/// Bytes per stored edge: two little-endian u32s (the `MAGQEDG1` record,
/// shared with the binary file body so the layouts cannot drift).
pub const SPILL_EDGE_LEN: u64 = BINARY_EDGE_LEN;

/// Edges read back per chunk when draining a spill run (1 MiB buffers).
pub const SPILL_READ_CHUNK: usize = 128 * 1024;

/// A per-process run nonce mixed into every temp-file name. The pid alone
/// is not enough once multiple worker *processes* share one spill or
/// segment directory: pids recycle between runs, and on a shared
/// filesystem two hosts can hold the same pid simultaneously. The nonce
/// folds in the process start time, so a recycled pid still gets fresh
/// names and a crashed run's leftovers can never be mistaken for (or
/// clobbered by) a live run's files.
pub fn run_nonce() -> u64 {
    static NONCE: OnceLock<u64> = OnceLock::new();
    *NONCE.get_or_init(|| {
        let t = std::time::SystemTime::now() // lint: time-ok(run nonce, never output-determining)
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        // SplitMix64-style finalization over (pid, start-time nanos).
        let mut h = t ^ (u64::from(std::process::id())).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        h ^ (h >> 31)
    })
}

/// A process-unique temp path inside `dir`: pid + run nonce + a
/// process-wide counter, tagged for debuggability. Safe for any number of
/// processes (even across hosts on a shared filesystem) to use against
/// the same directory — the shared naming scheme behind spill runs and
/// the distributed runtime's in-flight segment files.
pub fn unique_temp_path(dir: &Path, tag: &str, ext: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    dir.join(format!(
        "magquilt-tmp-{}-{:016x}-{seq}-{tag}.{ext}",
        std::process::id(),
        run_nonce(),
    ))
}

/// A process-unique spill path inside `dir` (the tag names the shard).
pub fn unique_spill_path(dir: &Path, tag: &str) -> PathBuf {
    unique_temp_path(dir, tag, "run")
}

/// Write `bytes` to `dir/name` atomically: stream into a process-unique
/// temp file, `sync_all`, then rename over the final name. A reader (or
/// a crash-resumed worker) therefore sees either no file or the complete
/// contents — never a torn write. Used for the distributed runtime's
/// small metadata files (completion markers); the temp is removed on any
/// failure.
pub fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> io::Result<()> {
    let tmp = unique_temp_path(dir, "meta", "part");
    let write = (|| -> io::Result<()> {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        std::fs::rename(&tmp, dir.join(name))
    })();
    if write.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    write
}

/// Streaming writer for one spill run.
pub struct SpillWriter {
    writer: BufWriter<File>,
    path: PathBuf,
    num_edges: u64,
}

impl std::fmt::Debug for SpillWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillWriter")
            .field("path", &self.path)
            .field("num_edges", &self.num_edges)
            .finish()
    }
}

impl SpillWriter {
    /// Create/truncate the spill file at `path`.
    pub fn create(path: impl AsRef<Path>) -> io::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let writer = BufWriter::new(File::create(&path)?);
        Ok(SpillWriter { writer, path, num_edges: 0 })
    }

    /// Append a run of edges.
    pub fn write_edges(&mut self, edges: &[Edge]) -> io::Result<()> {
        write_edge_records(&mut self.writer, edges)?;
        self.num_edges += edges.len() as u64;
        Ok(())
    }

    /// Edges written so far.
    pub fn num_edges(&self) -> u64 {
        self.num_edges
    }

    /// Flush and seal the run for reading back.
    pub fn finish(mut self) -> io::Result<SpillRun> {
        self.writer.flush()?;
        Ok(SpillRun { path: self.path.clone(), num_edges: self.num_edges, keep: false })
    }
}

/// A sealed spill run: a temp file of `num_edges` records. The file is
/// removed when the run is dropped (read it first).
pub struct SpillRun {
    path: PathBuf,
    num_edges: u64,
    /// Test hook: leak the file instead of removing it on drop.
    keep: bool,
}

impl std::fmt::Debug for SpillRun {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpillRun")
            .field("path", &self.path)
            .field("num_edges", &self.num_edges)
            .finish()
    }
}

impl SpillRun {
    /// Edge count of the run.
    pub fn num_edges(&self) -> u64 {
        self.num_edges
    }

    /// On-disk size of the run.
    pub fn bytes(&self) -> u64 {
        self.num_edges * SPILL_EDGE_LEN
    }

    /// Where the run lives.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stream the records back in chunks of at most `max_chunk_edges`,
    /// verifying the file still holds exactly the sealed record count —
    /// a short read means the spill file was truncated or tampered with,
    /// and silently delivering fewer edges would corrupt the output.
    pub fn for_each_chunk(
        &self,
        max_chunk_edges: usize,
        mut f: impl FnMut(&[Edge]) -> io::Result<()>,
    ) -> io::Result<()> {
        let chunk = max_chunk_edges.max(1);
        let mut reader = File::open(&self.path)?;
        let mut remaining = self.num_edges;
        let mut bytes = vec![0u8; chunk * SPILL_EDGE_LEN as usize];
        let mut edges: Vec<Edge> = Vec::with_capacity(chunk);
        while remaining > 0 {
            let take = remaining.min(chunk as u64) as usize;
            let buf = &mut bytes[..take * SPILL_EDGE_LEN as usize];
            reader.read_exact(buf).map_err(|e| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("spill run {} truncated: {e}", self.path.display()),
                )
            })?;
            edges.clear();
            for rec in buf.chunks_exact(SPILL_EDGE_LEN as usize) {
                let s = u32::from_le_bytes(rec[..4].try_into().expect("4-byte slice")); // lint: panic-ok(chunks_exact(8) guarantees the width)
                let t = u32::from_le_bytes(rec[4..].try_into().expect("4-byte slice")); // lint: panic-ok(chunks_exact(8) guarantees the width)
                edges.push((s, t));
            }
            f(&edges)?;
            remaining -= take as u64;
        }
        Ok(())
    }

    #[cfg(test)]
    fn keep_file(mut self) -> PathBuf {
        self.keep = true;
        self.path.clone()
    }
}

impl Drop for SpillRun {
    fn drop(&mut self) {
        if !self.keep {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_spill_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_in_chunks() {
        let path = unique_spill_path(&tmp_dir(), "shard2");
        let mut w = SpillWriter::create(&path).unwrap();
        let edges: Vec<Edge> = (0..1000u32).map(|i| (i, i.wrapping_mul(7) % 500)).collect();
        w.write_edges(&edges[..400]).unwrap();
        w.write_edges(&edges[400..]).unwrap();
        assert_eq!(w.num_edges(), 1000);
        let run = w.finish().unwrap();
        assert_eq!(run.num_edges(), 1000);
        assert_eq!(run.bytes(), 8000);
        let mut back = Vec::new();
        let mut chunks = 0;
        run.for_each_chunk(128, |c| {
            assert!(c.len() <= 128);
            chunks += 1;
            back.extend_from_slice(c);
            Ok(())
        })
        .unwrap();
        assert_eq!(back, edges);
        assert_eq!(chunks, 8); // ceil(1000 / 128)
    }

    #[test]
    fn drop_removes_file() {
        let path = unique_spill_path(&tmp_dir(), "shard0");
        let mut w = SpillWriter::create(&path).unwrap();
        w.write_edges(&[(1, 2)]).unwrap();
        let run = w.finish().unwrap();
        assert!(path.exists());
        drop(run);
        assert!(!path.exists());
    }

    #[test]
    fn truncated_run_is_an_error_not_short_data() {
        let path = unique_spill_path(&tmp_dir(), "shard1");
        let mut w = SpillWriter::create(&path).unwrap();
        w.write_edges(&[(1, 2), (3, 4), (5, 6)]).unwrap();
        let run = w.finish().unwrap();
        let kept = run.keep_file();
        // Re-seal a run claiming 3 edges over a file truncated to 1.
        let f = std::fs::OpenOptions::new().write(true).open(&kept).unwrap();
        f.set_len(SPILL_EDGE_LEN).unwrap();
        drop(f);
        let run = SpillRun { path: kept, num_edges: 3, keep: false };
        let err = run.for_each_chunk(16, |_| Ok(())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn unique_paths_do_not_collide() {
        let dir = tmp_dir();
        let a = unique_spill_path(&dir, "shard0");
        let b = unique_spill_path(&dir, "shard0");
        assert_ne!(a, b);
    }

    #[test]
    fn temp_names_carry_pid_and_run_nonce() {
        // Multiple worker processes share one --spill-dir / segment dir:
        // names must embed both the pid and the per-run nonce so a
        // recycled pid (or a second host on a shared filesystem) cannot
        // collide with this run's files.
        assert_eq!(run_nonce(), run_nonce(), "nonce is stable within a process");
        let p = unique_temp_path(&tmp_dir(), "seg3", "part");
        let name = p.file_name().unwrap().to_str().unwrap();
        assert!(name.contains(&std::process::id().to_string()), "pid in {name}");
        assert!(name.contains(&format!("{:016x}", run_nonce())), "nonce in {name}");
        assert!(name.ends_with("-seg3.part"), "tag + extension in {name}");
    }

    #[test]
    fn write_atomic_lands_complete_and_leaves_no_temp() {
        let dir = tmp_dir().join("atomic");
        std::fs::create_dir_all(&dir).unwrap();
        write_atomic(&dir, "marker.ok", b"format = 1\n").unwrap();
        assert_eq!(std::fs::read(dir.join("marker.ok")).unwrap(), b"format = 1\n");
        // Overwrite is atomic too (rename replaces the old contents).
        write_atomic(&dir, "marker.ok", b"format = 2\n").unwrap();
        assert_eq!(std::fs::read(dir.join("marker.ok")).unwrap(), b"format = 2\n");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.starts_with("magquilt-tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "temps left behind: {leftovers:?}");
    }

    #[test]
    fn empty_run_reads_nothing() {
        let path = unique_spill_path(&tmp_dir(), "empty");
        let run = SpillWriter::create(&path).unwrap().finish().unwrap();
        let mut called = false;
        run.for_each_chunk(8, |_| {
            called = true;
            Ok(())
        })
        .unwrap();
        assert!(!called);
    }
}
