//! Directed edge-list representation produced by all samplers.

use super::{Edge, NodeId};

/// A directed graph as a flat edge list plus node count.
///
/// Samplers may emit duplicate edges transiently; [`EdgeList::dedup`]
/// canonicalizes. Node ids must be `< num_nodes` (checked in debug builds
/// and by `validate`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EdgeList {
    num_nodes: usize,
    edges: Vec<Edge>,
}

impl EdgeList {
    /// Empty graph over `num_nodes` nodes.
    pub fn new(num_nodes: usize) -> Self {
        EdgeList { num_nodes, edges: Vec::new() }
    }

    /// With pre-allocated capacity for `cap` edges.
    pub fn with_capacity(num_nodes: usize, cap: usize) -> Self {
        EdgeList { num_nodes, edges: Vec::with_capacity(cap) }
    }

    /// Build from parts. Debug-asserts id bounds.
    pub fn from_edges(num_nodes: usize, edges: Vec<Edge>) -> Self {
        debug_assert!(edges
            .iter()
            .all(|&(s, t)| (s as usize) < num_nodes && (t as usize) < num_nodes));
        EdgeList { num_nodes, edges }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// Number of (directed) edges currently stored.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Append one edge.
    #[inline]
    pub fn push(&mut self, src: NodeId, dst: NodeId) {
        debug_assert!((src as usize) < self.num_nodes && (dst as usize) < self.num_nodes);
        self.edges.push((src, dst));
    }

    /// Append many edges.
    pub fn extend(&mut self, edges: impl IntoIterator<Item = Edge>) {
        self.edges.extend(edges);
    }

    /// Merge another edge list over the same node set (the quilting step).
    pub fn absorb(&mut self, other: EdgeList) {
        assert_eq!(self.num_nodes, other.num_nodes, "quilted pieces must share the node set");
        self.edges.extend(other.edges);
    }

    /// The edges as a slice.
    #[inline]
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Consume into the raw edge vector.
    pub fn into_edges(self) -> Vec<Edge> {
        self.edges
    }

    /// Sort and remove duplicate edges. Returns the number removed.
    pub fn dedup(&mut self) -> usize {
        let before = self.edges.len();
        self.edges.sort_unstable();
        self.edges.dedup();
        before - self.edges.len()
    }

    /// Count of self-loops.
    pub fn num_self_loops(&self) -> usize {
        self.edges.iter().filter(|&&(s, t)| s == t).count()
    }

    /// Out-degree of every node. `u64`: a `u32` accumulator silently
    /// wraps for hub nodes at multi-billion-edge scale.
    pub fn out_degrees(&self) -> Vec<u64> {
        let mut deg = vec![0u64; self.num_nodes];
        for &(s, _) in &self.edges {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degree of every node (`u64`, see [`Self::out_degrees`]).
    pub fn in_degrees(&self) -> Vec<u64> {
        let mut deg = vec![0u64; self.num_nodes];
        for &(_, t) in &self.edges {
            deg[t as usize] += 1;
        }
        deg
    }

    /// Check all invariants (ids in bounds). Returns Err description.
    pub fn validate(&self) -> Result<(), String> {
        for (idx, &(s, t)) in self.edges.iter().enumerate() {
            if s as usize >= self.num_nodes || t as usize >= self.num_nodes {
                return Err(format!(
                    "edge {idx} = ({s}, {t}) out of bounds for n = {}",
                    self.num_nodes
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_count() {
        let mut g = EdgeList::new(4);
        g.push(0, 1);
        g.push(1, 2);
        g.push(3, 0);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.num_nodes(), 4);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn dedup_removes_duplicates() {
        let mut g = EdgeList::from_edges(3, vec![(0, 1), (1, 2), (0, 1), (0, 1)]);
        let removed = g.dedup();
        assert_eq!(removed, 2);
        assert_eq!(g.edges(), &[(0, 1), (1, 2)]);
    }

    #[test]
    fn absorb_merges() {
        let mut a = EdgeList::from_edges(3, vec![(0, 1)]);
        let b = EdgeList::from_edges(3, vec![(1, 2), (2, 0)]);
        a.absorb(b);
        assert_eq!(a.num_edges(), 3);
    }

    #[test]
    #[should_panic]
    fn absorb_different_node_sets_panics() {
        let mut a = EdgeList::new(3);
        let b = EdgeList::new(4);
        a.absorb(b);
    }

    #[test]
    fn degrees() {
        let g = EdgeList::from_edges(3, vec![(0, 1), (0, 2), (1, 2), (2, 2)]);
        assert_eq!(g.out_degrees(), vec![2, 1, 1]);
        assert_eq!(g.in_degrees(), vec![0, 1, 3]);
        assert_eq!(g.num_self_loops(), 1);
    }

    #[test]
    fn validate_catches_out_of_bounds() {
        let g = EdgeList { num_nodes: 2, edges: vec![(0, 5)] };
        assert!(g.validate().is_err());
    }
}
