//! Graph algorithms used by the paper's property experiments (Fig. 9:
//! largest-SCC fraction) and the general statistics pipeline.

use super::{Csr, NodeId};

/// Sizes of all strongly connected components (iterative Tarjan).
///
/// Iterative so it handles the million-node graphs the samplers produce
/// without blowing the stack.
pub fn scc_sizes(g: &Csr) -> Vec<usize> {
    let n = g.num_nodes();
    const UNVISITED: u32 = u32::MAX;
    let mut index = vec![UNVISITED; n];
    let mut lowlink = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<NodeId> = Vec::new();
    let mut next_index = 0u32;
    let mut sizes = Vec::new();

    // Explicit DFS frame: (node, neighbor cursor).
    let mut call: Vec<(NodeId, usize)> = Vec::new();

    for root in 0..n as NodeId {
        if index[root as usize] != UNVISITED {
            continue;
        }
        call.push((root, 0));
        index[root as usize] = next_index;
        lowlink[root as usize] = next_index;
        next_index += 1;
        stack.push(root);
        on_stack[root as usize] = true;

        while let Some(&mut (v, ref mut cursor)) = call.last_mut() {
            let nbrs = g.neighbors(v);
            if *cursor < nbrs.len() {
                let w = nbrs[*cursor];
                *cursor += 1;
                if index[w as usize] == UNVISITED {
                    index[w as usize] = next_index;
                    lowlink[w as usize] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w as usize] = true;
                    call.push((w, 0));
                } else if on_stack[w as usize] {
                    lowlink[v as usize] = lowlink[v as usize].min(index[w as usize]);
                }
            } else {
                call.pop();
                if let Some(&mut (parent, _)) = call.last_mut() {
                    lowlink[parent as usize] =
                        lowlink[parent as usize].min(lowlink[v as usize]);
                }
                if lowlink[v as usize] == index[v as usize] {
                    // v roots an SCC: pop down to v.
                    let mut size = 0usize;
                    loop {
                        let w = stack.pop().expect("tarjan stack underflow");
                        on_stack[w as usize] = false;
                        size += 1;
                        if w == v {
                            break;
                        }
                    }
                    sizes.push(size);
                }
            }
        }
    }
    sizes
}

/// Size of the largest strongly connected component.
pub fn largest_scc_size(g: &Csr) -> usize {
    scc_sizes(g).into_iter().max().unwrap_or(0)
}

/// Size of the largest weakly connected component (union-find).
pub fn largest_wcc_size(g: &Csr) -> usize {
    let n = g.num_nodes();
    if n == 0 {
        return 0;
    }
    let mut uf = UnionFind::new(n);
    for v in 0..n as NodeId {
        for &w in g.neighbors(v) {
            uf.union(v as usize, w as usize);
        }
    }
    let mut counts = vec![0usize; n];
    for v in 0..n {
        counts[uf.find(v)] += 1;
    }
    counts.into_iter().max().unwrap_or(0)
}

/// Average local (directed, treating neighbors as the union of in/out)
/// clustering coefficient, estimated over `sample` random nodes for
/// tractability on large graphs. Deterministic in `seed`.
pub fn clustering_coefficient(g: &Csr, sample: usize, seed: u64) -> f64 {
    let n = g.num_nodes();
    if n == 0 {
        return 0.0;
    }
    let t = g.transpose();
    let mut rng = crate::rng::Rng::new(seed);
    let count = sample.min(n);
    let mut total = 0.0;
    for _ in 0..count {
        let v = rng.below(n as u64) as NodeId;
        // Undirected neighborhood = out ∪ in, excluding self.
        let mut nbrs: Vec<NodeId> = g
            .neighbors(v)
            .iter()
            .chain(t.neighbors(v).iter())
            .copied()
            .filter(|&w| w != v)
            .collect();
        nbrs.sort_unstable();
        nbrs.dedup();
        let k = nbrs.len();
        if k < 2 {
            continue;
        }
        let mut links = 0usize;
        for (i, &a) in nbrs.iter().enumerate() {
            for &b in &nbrs[i + 1..] {
                if g.has_edge(a, b) || g.has_edge(b, a) {
                    links += 1;
                }
            }
        }
        total += links as f64 / (k * (k - 1) / 2) as f64;
    }
    total / count as f64
}

/// Union-find with path halving and union by size.
struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            self.parent[x] = self.parent[self.parent[x] as usize];
            x = self.parent[x] as usize;
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn csr(n: usize, edges: Vec<(u32, u32)>) -> Csr {
        Csr::from_edge_list(&EdgeList::from_edges(n, edges))
    }

    #[test]
    fn scc_simple_cycle() {
        let g = csr(3, vec![(0, 1), (1, 2), (2, 0)]);
        let mut sizes = scc_sizes(&g);
        sizes.sort_unstable();
        assert_eq!(sizes, vec![3]);
    }

    #[test]
    fn scc_two_components_and_bridge() {
        // cycle {0,1} -> cycle {2,3}, plus isolated 4.
        let g = csr(5, vec![(0, 1), (1, 0), (1, 2), (2, 3), (3, 2)]);
        let mut sizes = scc_sizes(&g);
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 2, 2]);
        assert_eq!(largest_scc_size(&g), 2);
    }

    #[test]
    fn scc_dag_is_all_singletons() {
        let g = csr(4, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
        assert_eq!(largest_scc_size(&g), 1);
        assert_eq!(scc_sizes(&g).len(), 4);
    }

    #[test]
    fn scc_self_loop() {
        let g = csr(2, vec![(0, 0)]);
        assert_eq!(scc_sizes(&g).len(), 2);
        assert_eq!(largest_scc_size(&g), 1);
    }

    #[test]
    fn scc_deep_path_no_stack_overflow() {
        // 200k-node path: recursion would overflow; iterative must not.
        let n = 200_000;
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let g = csr(n, edges);
        assert_eq!(scc_sizes(&g).len(), n);
    }

    #[test]
    fn scc_matches_brute_force_on_random_graphs() {
        // Brute force: reachability closure via BFS both ways.
        let mut rng = crate::rng::Rng::new(99);
        for trial in 0..20 {
            let n = 2 + (trial % 8);
            let mut edges = Vec::new();
            for s in 0..n as u32 {
                for t in 0..n as u32 {
                    if rng.bernoulli(0.25) {
                        edges.push((s, t));
                    }
                }
            }
            let g = csr(n, edges.clone());
            let mut got = scc_sizes(&g);
            got.sort_unstable();
            let mut want = brute_scc_sizes(n, &edges);
            want.sort_unstable();
            assert_eq!(got, want, "trial {trial} n={n} edges={edges:?}");
        }
    }

    fn brute_scc_sizes(n: usize, edges: &[(u32, u32)]) -> Vec<usize> {
        let reach = |from: usize| -> Vec<bool> {
            let mut seen = vec![false; n];
            seen[from] = true;
            let mut stack = vec![from];
            while let Some(v) = stack.pop() {
                for &(s, t) in edges {
                    if s as usize == v && !seen[t as usize] {
                        seen[t as usize] = true;
                        stack.push(t as usize);
                    }
                }
            }
            seen
        };
        let fwd: Vec<Vec<bool>> = (0..n).map(reach).collect();
        let mut assigned = vec![false; n];
        let mut sizes = Vec::new();
        for v in 0..n {
            if assigned[v] {
                continue;
            }
            let members: Vec<usize> =
                (0..n).filter(|&w| fwd[v][w] && fwd[w][v] && !assigned[w]).collect();
            for &m in &members {
                assigned[m] = true;
            }
            sizes.push(members.len());
        }
        sizes
    }

    #[test]
    fn wcc_ignores_direction() {
        let g = csr(4, vec![(0, 1), (2, 1), (3, 3)]);
        assert_eq!(largest_wcc_size(&g), 3);
    }

    #[test]
    fn clustering_triangle() {
        let g = csr(3, vec![(0, 1), (1, 2), (2, 0)]);
        let c = clustering_coefficient(&g, 3, 1);
        assert!((c - 1.0).abs() < 1e-9, "c={c}");
    }

    #[test]
    fn clustering_star_is_zero() {
        let g = csr(4, vec![(0, 1), (0, 2), (0, 3)]);
        let c = clustering_coefficient(&g, 4, 1);
        assert_eq!(c, 0.0);
    }
}
