//! Edge sinks and the sharded streaming merge.
//!
//! The coordinator used to funnel every pre-dedup edge batch through one
//! merger thread into a single `Vec` and sort/dedup at the very end, so
//! peak memory scaled with the *pre*-dedup edge count and the merger
//! serialized all workers. This module replaces that with a **sharded**
//! design:
//!
//! * node ids are split into `S` disjoint source ranges ([`ShardSpec`]);
//!   workers route each sampled edge to the shard of its source node,
//! * each shard runs a [`ShardMerger`] that keeps its edges as one sorted,
//!   deduplicated run and merges every arriving batch **incrementally**
//!   (in place, backward, O(run + batch)); resident memory per shard is
//!   bounded by the post-dedup shard size plus batch-sized overhead (the
//!   in-flight batch and the merge's resize-by-batch scratch, ≤ two
//!   batches) — the pre-dedup multiset is never materialized anywhere,
//! * because shards partition the source range and each run is sorted by
//!   `(src, dst)`, concatenating the finished shards in index order *is*
//!   the globally sorted, deduplicated edge list — no final sort.
//!
//! Where the concatenation goes is abstracted by the [`EdgeSink`] trait:
//!
//! * [`CollectSink`] — in-memory [`EdgeList`] (the default, what
//!   `Coordinator::run` uses),
//! * [`CountingSink`] — degree vectors and an edge count only, for stats
//!   runs that never need to hold the graph,
//! * [`BinaryFileSink`] — streams the shards straight into the
//!   `MAGQEDG1` binary format, writing each shard as it finishes and
//!   back-patching the header edge count at the end, so samples larger
//!   than RAM can go directly to disk.
//!
//! Sinks consume shards strictly in ascending index order; a shard's
//! memory is released as soon as it is consumed.

use std::io;
use std::path::{Path, PathBuf};

use super::{Edge, EdgeList, NodeId};

/// Disjoint source-node ranges used to route edges to shard mergers.
///
/// Shard `i` owns sources `[i·w, (i+1)·w)` for width `w = ⌈n / S⌉`; the
/// last shard absorbs any remainder. Routing by *source* keeps duplicate
/// edges (same `(src, dst)` sampled by different pieces) on the same
/// shard, so per-shard dedup is global dedup.
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    num_shards: usize,
    shard_width: u64,
}

impl ShardSpec {
    /// Split `num_nodes` sources into `num_shards` ranges (both clamped
    /// to at least 1).
    pub fn new(num_nodes: usize, num_shards: usize) -> Self {
        let s = num_shards.max(1);
        let width = (num_nodes as u64).max(1).div_ceil(s as u64).max(1);
        ShardSpec { num_shards: s, shard_width: width }
    }

    /// Number of shards S.
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// The shard owning source node `src`.
    #[inline]
    pub fn shard_of(&self, src: NodeId) -> usize {
        ((src as u64 / self.shard_width) as usize).min(self.num_shards - 1)
    }
}

/// Per-shard merge statistics, reported by the coordinator so benches and
/// tests can verify the streaming-memory claim.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardMergeStats {
    /// Shard index.
    pub shard: usize,
    /// Final post-dedup edge count of the shard.
    pub edges: usize,
    /// Batches absorbed (non-empty sends from workers).
    pub batches: u64,
    /// Largest single batch absorbed (edges).
    pub max_batch: usize,
    /// Duplicate edges collapsed during merging (within and across
    /// batches).
    pub duplicates_dropped: u64,
    /// Peak resident edges **inside the merger**, counting the merge's
    /// transient scratch: the maximum over time of run + incoming batch,
    /// including the moment the run is resized by the batch length while
    /// the batch is still alive. By construction
    /// `<= edges + 2 · max_batch` — bounded by the post-dedup shard plus
    /// batch-sized overhead, never by the pre-dedup multiset.
    ///
    /// Scope: batches queued in the shard's bounded channel are *not*
    /// visible to the merger and are not counted here; the coordinator's
    /// `channel_capacity` (default 64 batches per shard) bounds that
    /// separately via backpressure.
    pub peak_resident: usize,
}

/// Incremental sorted-run merger for one shard.
///
/// Holds the shard's edges as a single sorted, deduplicated run and folds
/// each arriving batch in with an in-place backward merge: `O(run + batch)`
/// time per batch, and never more than `run + 2 · batch` edges resident
/// (the run grows by the batch length during the merge while the batch is
/// still alive).
#[derive(Debug, Default)]
pub struct ShardMerger {
    run: Vec<Edge>,
    stats: ShardMergeStats,
}

impl ShardMerger {
    /// Empty merger for shard `shard`.
    pub fn new(shard: usize) -> Self {
        ShardMerger { run: Vec::new(), stats: ShardMergeStats { shard, ..Default::default() } }
    }

    /// Absorb one (unsorted, possibly duplicated) batch of edges.
    pub fn absorb(&mut self, mut batch: Vec<Edge>) {
        if batch.is_empty() {
            return;
        }
        let raw = batch.len();
        self.stats.batches += 1;
        self.stats.max_batch = self.stats.max_batch.max(raw);
        self.stats.peak_resident = self.stats.peak_resident.max(self.run.len() + raw);
        batch.sort_unstable();
        batch.dedup();
        // The merge grows `run` by up to batch.len() while the batch is
        // still alive — count that transient honestly.
        self.stats.peak_resident =
            self.stats.peak_resident.max(self.run.len() + 2 * batch.len());
        let merged_away = merge_sorted_into(&mut self.run, &batch);
        self.stats.duplicates_dropped += (raw - batch.len() + merged_away) as u64;
        self.stats.edges = self.run.len();
    }

    /// Current post-dedup edge count.
    pub fn len(&self) -> usize {
        self.run.len()
    }

    /// Whether the shard is still empty.
    pub fn is_empty(&self) -> bool {
        self.run.is_empty()
    }

    /// Finish: the sorted, deduplicated run plus its merge statistics.
    pub fn finish(mut self) -> (Vec<Edge>, ShardMergeStats) {
        self.stats.edges = self.run.len();
        self.stats.peak_resident = self.stats.peak_resident.max(self.run.len());
        (self.run, self.stats)
    }
}

/// Merge the sorted, deduplicated `batch` into the sorted, deduplicated
/// `run`, in place (backward, from the ends). Returns the number of
/// cross-duplicates collapsed. `run` grows by at most `batch.len()`.
fn merge_sorted_into(run: &mut Vec<Edge>, batch: &[Edge]) -> usize {
    if batch.is_empty() {
        return 0;
    }
    if run.is_empty() {
        run.extend_from_slice(batch);
        return 0;
    }
    // Fast path: the batch lies entirely after the run (common when jobs
    // write localized blocks).
    if *run.last().expect("non-empty") < batch[0] {
        run.extend_from_slice(batch);
        return 0;
    }
    let r = run.len();
    let b = batch.len();
    run.resize(r + b, (0, 0));
    // Backward merge. Invariant: w >= i + j + 1 while j >= 0, so writes
    // never clobber unread run elements; equal keys consume both inputs
    // for one write (the dedup), which only widens the gap.
    let mut i = r as isize - 1;
    let mut j = b as isize - 1;
    let mut w = (r + b) as isize - 1;
    while i >= 0 && j >= 0 {
        let a = run[i as usize];
        let c = batch[j as usize];
        match a.cmp(&c) {
            std::cmp::Ordering::Equal => {
                run[w as usize] = a;
                i -= 1;
                j -= 1;
            }
            std::cmp::Ordering::Greater => {
                run[w as usize] = a;
                i -= 1;
            }
            std::cmp::Ordering::Less => {
                run[w as usize] = c;
                j -= 1;
            }
        }
        w -= 1;
    }
    while j >= 0 {
        run[w as usize] = batch[j as usize];
        j -= 1;
        w -= 1;
    }
    // If w == i the remaining run prefix is already in place and the
    // buffer is exactly full (no duplicates); otherwise shift the merged
    // suffix down over the gap left by collapsed duplicates.
    if w != i {
        while i >= 0 {
            run[w as usize] = run[i as usize];
            i -= 1;
            w -= 1;
        }
        let start = (w + 1) as usize;
        let len = r + b - start;
        run.copy_within(start.., 0);
        run.truncate(len);
    }
    debug_assert!(run.windows(2).all(|p| p[0] < p[1]), "merged run not strictly sorted");
    r + b - run.len()
}

/// Where the coordinator's sharded merge delivers the finished graph.
///
/// The coordinator calls [`begin`](EdgeSink::begin) once, then
/// [`consume_shard`](EdgeSink::consume_shard) for every shard **in
/// ascending index order** — each shard is sorted, deduplicated, and
/// strictly after every previously consumed shard in `(src, dst)` order —
/// and finally [`finish`](EdgeSink::finish).
pub trait EdgeSink {
    /// What the sink yields once every shard has been consumed.
    type Output;

    /// Called once before any shard is delivered.
    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()>;

    /// Consume finished shard `index`. The sink owns `edges` and should
    /// drop (or stream out) the buffer promptly — this is where the
    /// memory of a finished shard is released.
    fn consume_shard(&mut self, index: usize, edges: Vec<Edge>) -> io::Result<()>;

    /// All shards delivered; produce the output.
    fn finish(self) -> io::Result<Self::Output>;
}

/// In-memory sink: concatenates the shards into one [`EdgeList`] (already
/// globally sorted and deduplicated — no post-processing).
#[derive(Debug, Default)]
pub struct CollectSink {
    num_nodes: usize,
    edges: Vec<Edge>,
}

impl CollectSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EdgeSink for CollectSink {
    type Output = EdgeList;

    fn begin(&mut self, num_nodes: usize, _num_shards: usize) -> io::Result<()> {
        self.num_nodes = num_nodes;
        Ok(())
    }

    fn consume_shard(&mut self, _index: usize, mut edges: Vec<Edge>) -> io::Result<()> {
        if self.edges.is_empty() {
            self.edges = edges;
        } else {
            self.edges.append(&mut edges);
        }
        Ok(())
    }

    fn finish(self) -> io::Result<EdgeList> {
        Ok(EdgeList::from_edges(self.num_nodes, self.edges))
    }
}

/// Degree/count aggregate produced by [`CountingSink`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegreeCounts {
    /// Node count.
    pub num_nodes: usize,
    /// Post-dedup edge count.
    pub num_edges: u64,
    /// Self-loop count.
    pub self_loops: u64,
    /// Out-degree of every node.
    pub out_degrees: Vec<u64>,
    /// In-degree of every node.
    pub in_degrees: Vec<u64>,
}

impl DegreeCounts {
    /// Largest out-degree.
    pub fn max_out_degree(&self) -> u64 {
        self.out_degrees.iter().copied().max().unwrap_or(0)
    }

    /// Largest in-degree.
    pub fn max_in_degree(&self) -> u64 {
        self.in_degrees.iter().copied().max().unwrap_or(0)
    }
}

/// Statistics-only sink: accumulates degrees and counts, dropping each
/// shard's edges immediately — the graph itself is never held.
#[derive(Debug, Default)]
pub struct CountingSink {
    counts: Option<DegreeCounts>,
}

impl CountingSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EdgeSink for CountingSink {
    type Output = DegreeCounts;

    fn begin(&mut self, num_nodes: usize, _num_shards: usize) -> io::Result<()> {
        self.counts = Some(DegreeCounts {
            num_nodes,
            num_edges: 0,
            self_loops: 0,
            out_degrees: vec![0u64; num_nodes],
            in_degrees: vec![0u64; num_nodes],
        });
        Ok(())
    }

    fn consume_shard(&mut self, _index: usize, edges: Vec<Edge>) -> io::Result<()> {
        let counts = self.counts.as_mut().expect("begin not called");
        counts.num_edges += edges.len() as u64;
        for (s, t) in edges {
            counts.out_degrees[s as usize] += 1;
            counts.in_degrees[t as usize] += 1;
            if s == t {
                counts.self_loops += 1;
            }
        }
        Ok(())
    }

    fn finish(self) -> io::Result<DegreeCounts> {
        self.counts
            .ok_or_else(|| io::Error::other("CountingSink finished before begin"))
    }
}

/// Streams shards straight into the `MAGQEDG1` binary edge-list format.
///
/// `begin` writes the header with a placeholder edge count; every shard is
/// appended as it finishes (the shard order makes the file globally
/// sorted); `finish` seeks back and patches the true count. Peak memory is
/// one shard, not the graph.
#[derive(Debug)]
pub struct BinaryFileSink {
    path: PathBuf,
    writer: Option<super::io::BinaryEdgeWriter>,
    num_edges: u64,
}

impl BinaryFileSink {
    /// Sink writing to `path` (created/truncated at `begin`).
    pub fn create(path: impl AsRef<Path>) -> Self {
        BinaryFileSink { path: path.as_ref().to_path_buf(), writer: None, num_edges: 0 }
    }
}

impl EdgeSink for BinaryFileSink {
    /// Number of edges written.
    type Output = u64;

    fn begin(&mut self, num_nodes: usize, _num_shards: usize) -> io::Result<()> {
        self.writer = Some(super::io::BinaryEdgeWriter::create(&self.path, num_nodes)?);
        Ok(())
    }

    fn consume_shard(&mut self, _index: usize, edges: Vec<Edge>) -> io::Result<()> {
        let w = self.writer.as_mut().expect("begin not called");
        w.write_edges(&edges)?;
        self.num_edges += edges.len() as u64;
        Ok(())
    }

    fn finish(mut self) -> io::Result<u64> {
        let w = self
            .writer
            .take()
            .ok_or_else(|| io::Error::other("BinaryFileSink finished before begin"))?;
        w.finalize(self.num_edges)?;
        Ok(self.num_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn edges_of(pairs: &[(u32, u32)]) -> Vec<Edge> {
        pairs.to_vec()
    }

    #[test]
    fn shard_spec_partitions_sources() {
        let spec = ShardSpec::new(10, 3);
        assert_eq!(spec.num_shards(), 3);
        let shards: Vec<usize> = (0..10u32).map(|s| spec.shard_of(s)).collect();
        // Non-decreasing, starts at 0, ends at S-1, covers disjoint ranges.
        assert!(shards.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(shards[0], 0);
        assert_eq!(*shards.last().unwrap(), 2);
    }

    #[test]
    fn shard_spec_more_shards_than_nodes() {
        let spec = ShardSpec::new(2, 8);
        assert_eq!(spec.shard_of(0), 0);
        assert_eq!(spec.shard_of(1), 1);
    }

    #[test]
    fn shard_spec_single_shard_takes_all() {
        let spec = ShardSpec::new(1000, 1);
        for s in [0u32, 17, 999] {
            assert_eq!(spec.shard_of(s), 0);
        }
    }

    #[test]
    fn merge_into_empty_run() {
        let mut run = Vec::new();
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(1, 2), (3, 4)])), 0);
        assert_eq!(run, edges_of(&[(1, 2), (3, 4)]));
    }

    #[test]
    fn merge_disjoint_appends() {
        let mut run = edges_of(&[(0, 1), (1, 0)]);
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(2, 0), (2, 1)])), 0);
        assert_eq!(run, edges_of(&[(0, 1), (1, 0), (2, 0), (2, 1)]));
    }

    #[test]
    fn merge_interleaved_with_duplicates() {
        let mut run = edges_of(&[(0, 1), (2, 2), (5, 0)]);
        let dropped = merge_sorted_into(&mut run, &edges_of(&[(0, 0), (2, 2), (5, 0), (7, 7)]));
        assert_eq!(dropped, 2);
        assert_eq!(run, edges_of(&[(0, 0), (0, 1), (2, 2), (5, 0), (7, 7)]));
    }

    #[test]
    fn merge_batch_entirely_before_run() {
        let mut run = edges_of(&[(5, 5), (6, 6)]);
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(1, 1), (2, 2)])), 0);
        assert_eq!(run, edges_of(&[(1, 1), (2, 2), (5, 5), (6, 6)]));
    }

    #[test]
    fn merge_all_duplicates_collapses() {
        let mut run = edges_of(&[(1, 1), (2, 2)]);
        let dropped = merge_sorted_into(&mut run, &edges_of(&[(1, 1), (2, 2)]));
        assert_eq!(dropped, 2);
        assert_eq!(run, edges_of(&[(1, 1), (2, 2)]));
    }

    #[test]
    fn merge_randomized_matches_sort_dedup() {
        let mut rng = Rng::new(91);
        for case in 0..200 {
            let mut run: Vec<Edge> = (0..rng.below(40))
                .map(|_| (rng.below(16) as u32, rng.below(16) as u32))
                .collect();
            run.sort_unstable();
            run.dedup();
            let mut batch: Vec<Edge> = (0..rng.below(40))
                .map(|_| (rng.below(16) as u32, rng.below(16) as u32))
                .collect();
            batch.sort_unstable();
            batch.dedup();
            let mut want: Vec<Edge> = run.iter().chain(batch.iter()).copied().collect();
            want.sort_unstable();
            want.dedup();
            let before = run.len() + batch.len();
            let dropped = merge_sorted_into(&mut run, &batch);
            assert_eq!(run, want, "case {case}");
            assert_eq!(dropped, before - want.len(), "case {case}");
        }
    }

    #[test]
    fn shard_merger_tracks_stats_and_memory_bound() {
        let mut m = ShardMerger::new(3);
        m.absorb(edges_of(&[(4, 1), (0, 1), (4, 1)])); // one within-batch dup
        m.absorb(edges_of(&[(0, 1), (2, 2)])); // one cross-batch dup
        m.absorb(Vec::new()); // ignored
        let (run, stats) = m.finish();
        assert_eq!(run, edges_of(&[(0, 1), (2, 2), (4, 1)]));
        assert_eq!(stats.shard, 3);
        assert_eq!(stats.edges, 3);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.max_batch, 3);
        assert_eq!(stats.duplicates_dropped, 2);
        // The streaming-memory claim: never more resident than the final
        // run plus batch-sized merge overhead.
        assert!(stats.peak_resident <= stats.edges + 2 * stats.max_batch);
    }

    #[test]
    fn collect_sink_concatenates_shards() {
        let mut sink = CollectSink::new();
        sink.begin(8, 2).unwrap();
        sink.consume_shard(0, edges_of(&[(0, 3), (1, 1)])).unwrap();
        sink.consume_shard(1, edges_of(&[(4, 0), (7, 7)])).unwrap();
        let g = sink.finish().unwrap();
        assert_eq!(g.num_nodes(), 8);
        assert_eq!(g.edges(), &[(0, 3), (1, 1), (4, 0), (7, 7)]);
    }

    #[test]
    fn counting_sink_matches_collected_degrees() {
        let shard0 = edges_of(&[(0, 1), (0, 2), (1, 1)]);
        let shard1 = edges_of(&[(2, 0), (3, 1)]);

        let mut collect = CollectSink::new();
        collect.begin(4, 2).unwrap();
        collect.consume_shard(0, shard0.clone()).unwrap();
        collect.consume_shard(1, shard1.clone()).unwrap();
        let g = collect.finish().unwrap();

        let mut count = CountingSink::new();
        count.begin(4, 2).unwrap();
        count.consume_shard(0, shard0).unwrap();
        count.consume_shard(1, shard1).unwrap();
        let c = count.finish().unwrap();

        assert_eq!(c.num_edges, g.num_edges() as u64);
        assert_eq!(c.self_loops, g.num_self_loops() as u64);
        assert_eq!(c.out_degrees, g.out_degrees());
        assert_eq!(c.in_degrees, g.in_degrees());
        assert_eq!(c.max_out_degree(), 2);
        assert_eq!(c.max_in_degree(), 3);
    }

    #[test]
    fn binary_file_sink_roundtrips() {
        let dir = std::env::temp_dir().join("magquilt_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sink.bin");
        let mut sink = BinaryFileSink::create(&path);
        sink.begin(6, 2).unwrap();
        sink.consume_shard(0, edges_of(&[(0, 5), (2, 2)])).unwrap();
        sink.consume_shard(1, edges_of(&[(3, 0), (5, 4)])).unwrap();
        let written = sink.finish().unwrap();
        assert_eq!(written, 4);
        let g = super::super::read_edge_list_binary(&path).unwrap();
        assert_eq!(g.num_nodes(), 6);
        assert_eq!(g.edges(), &[(0, 5), (2, 2), (3, 0), (5, 4)]);
    }
}
