//! Edge sinks and the sharded streaming merge.
//!
//! The coordinator used to funnel every pre-dedup edge batch through one
//! merger thread into a single `Vec` and sort/dedup at the very end, so
//! peak memory scaled with the *pre*-dedup edge count and the merger
//! serialized all workers. This module replaces that with a **sharded**
//! design:
//!
//! * node ids are split into `S` disjoint source ranges ([`ShardSpec`]);
//!   workers route each sampled edge to the shard of its source node,
//! * each shard runs a [`ShardMerger`] that keeps its edges as one sorted,
//!   deduplicated run and merges every arriving batch **incrementally**
//!   (in place, backward, O(run + batch)); resident memory per shard is
//!   bounded by the post-dedup shard size plus batch-sized overhead (the
//!   in-flight batch and the merge's resize-by-batch scratch, ≤ two
//!   batches) — the pre-dedup multiset is never materialized anywhere,
//! * because shards partition the source range and each run is sorted by
//!   `(src, dst)`, stitching the finished shards together in index order
//!   *is* the globally sorted, deduplicated edge list — no final sort.
//!
//! # The shard-addressable sink protocol
//!
//! Where the stitched edges go is abstracted by the [`EdgeSink`] trait.
//! Shards are delivered **in completion order, not index order**: under
//! source-range skew a late-indexed shard routinely finishes first, and
//! forcing index order would leave its entire run buffered in its merger
//! until every earlier shard caught up — reintroducing the residency
//! spike the streaming merge exists to avoid. The protocol is:
//!
//! 1. [`begin(num_nodes, num_shards)`](EdgeSink::begin) — once, before
//!    any shard.
//! 2. Per finished shard, in *any* order:
//!    [`begin_shard(index, edge_count_hint)`](EdgeSink::begin_shard)
//!    announcing the shard's exact final edge count, then
//!    [`accept_shard(index, run)`](EdgeSink::accept_shard) handing over
//!    the sorted, deduplicated run. Each index is delivered exactly once.
//!    The sink reports how it handled the shard via
//!    [`ShardDisposition`]: written through, held in memory, or spilled
//!    to a temp file.
//! 3. [`finalize()`](EdgeSink::finalize) — every shard delivered;
//!    produce the output.
//!
//! The three sinks handle out-of-order delivery with different budgets:
//!
//! * [`CollectSink`] — appends each frontier arrival at its offset in
//!   the one output vector (freeing the run's buffer) and holds only the
//!   runs that genuinely arrived early, yielding the [`EdgeList`]
//!   (already globally sorted) with no second full-size copy.
//! * [`CountingSink`] — order-indifferent for free: degrees and counts
//!   commute, every run is folded and dropped on arrival; the graph is
//!   never held.
//! * [`BinaryFileSink`] — the file is inherently sequential, so an
//!   out-of-order shard is *deferred*: held in memory while the deferred
//!   total fits the [spill budget](BinaryFileSink::spill_budget), spilled
//!   to a temp [`SpillRun`] file otherwise. When
//!   the file frontier reaches a deferred shard it is concatenated into
//!   its slot (spill files stream back in bounded chunks and are deleted)
//!   — so sink-side memory never exceeds the budget plus one in-flight
//!   run, no matter how extreme the completion skew.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

use super::spill::{unique_spill_path, SpillRun, SpillWriter, SPILL_EDGE_LEN, SPILL_READ_CHUNK};
use super::{Edge, EdgeList, NodeId};

/// Disjoint source-node ranges used to route edges to shard mergers.
///
/// Shard `i` owns sources `[i·w, (i+1)·w)` for width `w = ⌈n / S⌉`; the
/// last shard absorbs any remainder. Routing by *source* keeps duplicate
/// edges (same `(src, dst)` sampled by different pieces) on the same
/// shard, so per-shard dedup is global dedup.
///
/// `S` is clamped to `min(S, n)`: a shard count beyond the node count
/// would only manufacture empty trailing shards (and misleading
/// `shard_stats` rows) since width is already 1. [`Self::num_shards`]
/// always reports the *effective* count.
#[derive(Debug, Clone, Copy)]
pub struct ShardSpec {
    num_shards: usize,
    shard_width: u64,
    num_nodes: u64,
}

impl ShardSpec {
    /// Split `num_nodes` sources into `num_shards` ranges (shard count
    /// clamped to `[1, max(num_nodes, 1)]`).
    pub fn new(num_nodes: usize, num_shards: usize) -> Self {
        let n = (num_nodes as u64).max(1);
        let s = (num_shards.max(1) as u64).min(n);
        let width = n.div_ceil(s).max(1);
        ShardSpec { num_shards: s as usize, shard_width: width, num_nodes: n }
    }

    /// Effective number of shards S (after clamping).
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// The source-node count the spec routes over.
    #[inline]
    pub fn num_nodes(&self) -> u64 {
        self.num_nodes
    }

    /// The shard owning source node `src`.
    ///
    /// `src` must be a valid node id: an id at or beyond `num_nodes` is
    /// an upstream sampler bug, and silently clamping it into the last
    /// shard (as this method once did) masks it. Debug builds panic;
    /// release callers that handle untrusted ids use
    /// [`Self::checked_shard_of`].
    #[inline]
    pub fn shard_of(&self, src: NodeId) -> usize {
        debug_assert!(
            (src as u64) < self.num_nodes,
            "source id {src} out of range for {} nodes",
            self.num_nodes
        );
        ((src as u64 / self.shard_width) as usize).min(self.num_shards - 1)
    }

    /// The shard owning `src`, or `None` when `src` is not a valid node
    /// id — the error-propagating form the worker routing path uses.
    #[inline]
    pub fn checked_shard_of(&self, src: NodeId) -> Option<usize> {
        if (src as u64) < self.num_nodes {
            Some(((src as u64 / self.shard_width) as usize).min(self.num_shards - 1))
        } else {
            None
        }
    }
}

/// How a sink handled one delivered shard — fed back into that shard's
/// [`ShardMergeStats`] so tests, benches, and the CLI can see whether the
/// out-of-order machinery engaged and what it cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardDisposition {
    /// Consumed immediately (written through, counted, or slotted);
    /// no deferred copy exists anywhere.
    Streamed,
    /// Arrived ahead of the file frontier and is held in memory within
    /// the spill budget.
    Deferred {
        /// Bytes held.
        bytes: u64,
    },
    /// Arrived ahead of the file frontier over budget and was streamed
    /// to a temp spill file.
    Spilled {
        /// Bytes written to the spill file.
        bytes: u64,
    },
}

/// Per-shard merge statistics, reported by the coordinator so benches and
/// tests can verify the streaming-memory claim.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardMergeStats {
    /// Shard index.
    pub shard: usize,
    /// Final post-dedup edge count of the shard.
    pub edges: usize,
    /// Batches absorbed (non-empty sends from workers).
    pub batches: u64,
    /// Largest single batch absorbed (edges).
    pub max_batch: usize,
    /// Duplicate edges collapsed during merging (within and across
    /// batches).
    pub duplicates_dropped: u64,
    /// Peak resident edges **inside the merger**, counting the merge's
    /// transient scratch: the maximum over time of run + incoming batch,
    /// including the moment the run is resized by the batch length while
    /// the batch is still alive. By construction
    /// `<= edges + 2 · max_batch` — bounded by the post-dedup shard plus
    /// batch-sized overhead, never by the pre-dedup multiset.
    ///
    /// Scope: batches queued in the shard's bounded channel are *not*
    /// visible to the merger and are not counted here; the coordinator's
    /// `channel_capacity` (default 64 batches per shard) bounds that
    /// separately via backpressure.
    pub peak_resident: usize,
    /// Whether the sink deferred this shard (it finished ahead of the
    /// output frontier) — in memory or on disk.
    pub deferred: bool,
    /// Spill runs the sink wrote for this shard (0 or 1).
    pub spill_runs: u64,
    /// Bytes the sink spilled to disk for this shard.
    pub spill_bytes: u64,
}

impl ShardMergeStats {
    /// Record how the sink disposed of this shard's run.
    pub fn record_disposition(&mut self, disposition: ShardDisposition) {
        match disposition {
            ShardDisposition::Streamed => {}
            ShardDisposition::Deferred { .. } => self.deferred = true,
            ShardDisposition::Spilled { bytes } => {
                self.deferred = true;
                self.spill_runs += 1;
                self.spill_bytes += bytes;
            }
        }
    }
}

/// Aggregate spill/deferral picture of one run, summed over
/// [`ShardMergeStats`] — what the CLI prints as the `spill:` line.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillSummary {
    /// Shards the sink deferred (finished ahead of the output frontier).
    pub deferred_shards: usize,
    /// Shards that went to a temp spill file.
    pub spilled_shards: usize,
    /// Spill runs written.
    pub spill_runs: u64,
    /// Bytes written to spill files.
    pub spill_bytes: u64,
}

/// Sum the spill/deferral columns of a run's shard stats.
pub fn summarize_spill(stats: &[ShardMergeStats]) -> SpillSummary {
    let mut sum = SpillSummary::default();
    for s in stats {
        sum.deferred_shards += s.deferred as usize;
        sum.spilled_shards += (s.spill_runs > 0) as usize;
        sum.spill_runs += s.spill_runs;
        sum.spill_bytes += s.spill_bytes;
    }
    sum
}

/// Incremental sorted-run merger for one shard.
///
/// Holds the shard's edges as a single sorted, deduplicated run and folds
/// each arriving batch in with an in-place backward merge: `O(run + batch)`
/// time per batch, and never more than `run + 2 · batch` edges resident
/// (the run grows by the batch length during the merge while the batch is
/// still alive).
#[derive(Debug, Default)]
pub struct ShardMerger {
    run: Vec<Edge>,
    stats: ShardMergeStats,
}

impl ShardMerger {
    /// Empty merger for shard `shard`.
    pub fn new(shard: usize) -> Self {
        Self::with_capacity(shard, 0)
    }

    /// Empty merger for shard `shard` with room for `edges` edges — when
    /// the incoming total is known up front (e.g. from validated segment
    /// headers), pre-sizing skips the doubling reallocations of the first
    /// absorbs. The pre-dedup total is a safe upper bound for the run.
    pub fn with_capacity(shard: usize, edges: usize) -> Self {
        ShardMerger {
            run: Vec::with_capacity(edges),
            stats: ShardMergeStats { shard, ..Default::default() },
        }
    }

    /// Absorb one (unsorted, possibly duplicated) batch of edges.
    pub fn absorb(&mut self, mut batch: Vec<Edge>) {
        if batch.is_empty() {
            return;
        }
        let raw = batch.len();
        self.stats.batches += 1;
        self.stats.max_batch = self.stats.max_batch.max(raw);
        self.stats.peak_resident = self.stats.peak_resident.max(self.run.len() + raw);
        batch.sort_unstable();
        batch.dedup();
        // The merge grows `run` by up to batch.len() while the batch is
        // still alive — count that transient honestly.
        self.stats.peak_resident =
            self.stats.peak_resident.max(self.run.len() + 2 * batch.len());
        let merged_away = merge_sorted_into(&mut self.run, &batch);
        self.stats.duplicates_dropped += (raw - batch.len() + merged_away) as u64;
        self.stats.edges = self.run.len();
    }

    /// Current post-dedup edge count.
    pub fn len(&self) -> usize {
        self.run.len()
    }

    /// Whether the shard is still empty.
    pub fn is_empty(&self) -> bool {
        self.run.is_empty()
    }

    /// Finish: the sorted, deduplicated run plus its merge statistics.
    pub fn finish(mut self) -> (Vec<Edge>, ShardMergeStats) {
        self.stats.edges = self.run.len();
        self.stats.peak_resident = self.stats.peak_resident.max(self.run.len());
        (self.run, self.stats)
    }
}

/// Merge the sorted, deduplicated `batch` into the sorted, deduplicated
/// `run`, in place (backward, from the ends). Returns the number of
/// cross-duplicates collapsed. `run` grows by at most `batch.len()`.
fn merge_sorted_into(run: &mut Vec<Edge>, batch: &[Edge]) -> usize {
    if batch.is_empty() {
        return 0;
    }
    if run.is_empty() {
        run.extend_from_slice(batch);
        return 0;
    }
    // Fast path: the batch lies entirely after the run (common when jobs
    // write localized blocks).
    if *run.last().expect("non-empty") < batch[0] { // lint: panic-ok(guarded by the is_empty early return above)
        run.extend_from_slice(batch);
        return 0;
    }
    let r = run.len();
    let b = batch.len();
    run.resize(r + b, (0, 0));
    // Backward merge. Invariant: w >= i + j + 1 while j >= 0, so writes
    // never clobber unread run elements; equal keys consume both inputs
    // for one write (the dedup), which only widens the gap.
    let mut i = r as isize - 1;
    let mut j = b as isize - 1;
    let mut w = (r + b) as isize - 1;
    while i >= 0 && j >= 0 {
        let a = run[i as usize];
        let c = batch[j as usize];
        match a.cmp(&c) {
            std::cmp::Ordering::Equal => {
                run[w as usize] = a;
                i -= 1;
                j -= 1;
            }
            std::cmp::Ordering::Greater => {
                run[w as usize] = a;
                i -= 1;
            }
            std::cmp::Ordering::Less => {
                run[w as usize] = c;
                j -= 1;
            }
        }
        w -= 1;
    }
    while j >= 0 {
        run[w as usize] = batch[j as usize];
        j -= 1;
        w -= 1;
    }
    // If w == i the remaining run prefix is already in place and the
    // buffer is exactly full (no duplicates); otherwise shift the merged
    // suffix down over the gap left by collapsed duplicates.
    if w != i {
        while i >= 0 {
            run[w as usize] = run[i as usize];
            i -= 1;
            w -= 1;
        }
        let start = (w + 1) as usize;
        let len = r + b - start;
        run.copy_within(start.., 0);
        run.truncate(len);
    }
    debug_assert!(run.windows(2).all(|p| p[0] < p[1]), "merged run not strictly sorted");
    r + b - run.len()
}

/// Where the coordinator's sharded merge delivers the finished graph.
///
/// See the [module docs](self) for the full protocol. In short: one
/// [`begin`](EdgeSink::begin), then per finished shard — **in completion
/// order, which under skew is not index order** —
/// [`begin_shard`](EdgeSink::begin_shard) followed by
/// [`accept_shard`](EdgeSink::accept_shard), and one
/// [`finalize`](EdgeSink::finalize) once every shard index in
/// `0..num_shards` has been delivered exactly once. Each delivered run is
/// sorted, deduplicated, and disjoint from (strictly ordered against)
/// every other shard's run in `(src, dst)` order.
pub trait EdgeSink {
    /// What the sink yields once every shard has been delivered.
    type Output;

    /// Called once before any shard is delivered.
    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()>;

    /// Announce that shard `index` is about to be delivered with exactly
    /// `edge_count_hint` edges — sizing information for placement or
    /// spill decisions. Always immediately followed by
    /// [`accept_shard`](EdgeSink::accept_shard) with the same index.
    fn begin_shard(&mut self, index: usize, edge_count_hint: usize) -> io::Result<()> {
        let _ = (index, edge_count_hint);
        Ok(())
    }

    /// Deliver finished shard `index`. The sink owns `run` and should
    /// consume, place, or spill it promptly — this is where a finished
    /// shard's memory is released. Returns how the run was disposed of.
    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition>;

    /// All shards delivered; produce the output.
    fn finalize(self) -> io::Result<Self::Output>;
}

/// In-memory sink: appends each shard at its offset in one growing edge
/// vector (already globally sorted and deduplicated — no
/// post-processing). A shard arriving at the frontier — every
/// lower-indexed shard already placed — is appended immediately and its
/// buffer freed; an out-of-order shard waits in `pending` until the
/// frontier reaches it, so peak memory is the edge list plus only the
/// runs that genuinely arrived early, never a second full-size copy.
#[derive(Debug, Default)]
pub struct CollectSink {
    num_nodes: usize,
    num_shards: usize,
    /// Every shard below this index is already appended to `edges`.
    next_shard: usize,
    edges: Vec<Edge>,
    /// Out-of-order runs waiting for the frontier, keyed by index.
    pending: BTreeMap<usize, Vec<Edge>>,
}

impl CollectSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EdgeSink for CollectSink {
    type Output = EdgeList;

    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()> {
        self.num_nodes = num_nodes;
        self.num_shards = num_shards.max(1);
        Ok(())
    }

    fn begin_shard(&mut self, index: usize, edge_count_hint: usize) -> io::Result<()> {
        // A frontier arrival is appended in place: grow the buffer once,
        // up front, instead of mid-append.
        if index == self.next_shard {
            self.edges.reserve(edge_count_hint);
        }
        Ok(())
    }

    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition> {
        if index >= self.num_shards {
            return Err(io::Error::other(format!("shard index {index} out of range")));
        }
        if index < self.next_shard || self.pending.contains_key(&index) {
            return Err(io::Error::other(format!("shard {index} delivered twice")));
        }
        if index > self.next_shard {
            let bytes = run.len() as u64 * SPILL_EDGE_LEN;
            self.pending.insert(index, run);
            return Ok(ShardDisposition::Deferred { bytes });
        }
        // At the frontier: the current length IS shard `index`'s offset
        // (the sizes of every earlier shard, already appended).
        self.edges.extend_from_slice(&run);
        drop(run);
        self.next_shard += 1;
        while let Some(next) = self.pending.remove(&self.next_shard) {
            self.edges.extend_from_slice(&next);
            self.next_shard += 1;
        }
        Ok(ShardDisposition::Streamed)
    }

    fn finalize(self) -> io::Result<EdgeList> {
        if self.next_shard < self.num_shards {
            return Err(io::Error::other(format!(
                "shard {} never delivered ({} of {} placed)",
                self.next_shard, self.next_shard, self.num_shards
            )));
        }
        Ok(EdgeList::from_edges(self.num_nodes, self.edges))
    }
}

/// Degree/count aggregate produced by [`CountingSink`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegreeCounts {
    /// Node count.
    pub num_nodes: usize,
    /// Post-dedup edge count.
    pub num_edges: u64,
    /// Self-loop count.
    pub self_loops: u64,
    /// Out-degree of every node.
    pub out_degrees: Vec<u64>,
    /// In-degree of every node.
    pub in_degrees: Vec<u64>,
}

impl DegreeCounts {
    /// Largest out-degree.
    pub fn max_out_degree(&self) -> u64 {
        self.out_degrees.iter().copied().max().unwrap_or(0)
    }

    /// Largest in-degree.
    pub fn max_in_degree(&self) -> u64 {
        self.in_degrees.iter().copied().max().unwrap_or(0)
    }
}

/// Statistics-only sink: accumulates degrees and counts, dropping each
/// shard's edges immediately — the graph itself is never held. Degree
/// sums commute, so shards are consumed in whatever order they finish at
/// zero extra cost.
#[derive(Debug, Default)]
pub struct CountingSink {
    counts: Option<DegreeCounts>,
    seen: Vec<bool>,
}

impl CountingSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EdgeSink for CountingSink {
    type Output = DegreeCounts;

    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()> {
        self.counts = Some(DegreeCounts {
            num_nodes,
            num_edges: 0,
            self_loops: 0,
            out_degrees: vec![0u64; num_nodes],
            in_degrees: vec![0u64; num_nodes],
        });
        self.seen = vec![false; num_shards.max(1)];
        Ok(())
    }

    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition> {
        let counts = self
            .counts
            .as_mut()
            .ok_or_else(|| io::Error::other("accept_shard before begin"))?;
        let seen = self
            .seen
            .get_mut(index)
            .ok_or_else(|| io::Error::other(format!("shard index {index} out of range")))?;
        if std::mem::replace(seen, true) {
            return Err(io::Error::other(format!("shard {index} delivered twice")));
        }
        counts.num_edges += run.len() as u64;
        for (s, t) in run {
            counts.out_degrees[s as usize] += 1;
            counts.in_degrees[t as usize] += 1;
            if s == t {
                counts.self_loops += 1;
            }
        }
        Ok(ShardDisposition::Streamed)
    }

    fn finalize(self) -> io::Result<DegreeCounts> {
        self.counts
            .ok_or_else(|| io::Error::other("CountingSink finalized before begin"))
    }
}

/// Default in-memory budget for out-of-order shards in
/// [`BinaryFileSink`]: 256 MiB of deferred edges before spilling.
pub const DEFAULT_SPILL_BUDGET: u64 = 256 << 20;

/// A shard held back because the file frontier has not reached it yet.
#[derive(Debug)]
enum PendingShard {
    /// Held in memory (within the spill budget).
    Memory(Vec<Edge>),
    /// Streamed to a temp spill file.
    Spilled(SpillRun),
}

/// Streams shards straight into the `MAGQEDG1` binary edge-list format.
///
/// `begin` writes the header with a placeholder edge count; each shard
/// that arrives at the file frontier (all lower-indexed shards already
/// written) is appended directly, which keeps the file globally sorted.
/// A shard that finishes *ahead* of the frontier is deferred: held in
/// memory while the deferred total fits [`Self::spill_budget`], spilled
/// to a temp file in [`Self::spill_dir`] otherwise, and concatenated into
/// its slot (streamed back in bounded chunks, spill file deleted) once
/// the frontier catches up. `finalize` back-patches the true edge count
/// after the data is durable. Peak sink-side memory is the spill budget
/// plus one in-flight shard — never the graph.
#[derive(Debug)]
pub struct BinaryFileSink {
    path: PathBuf,
    spill_dir: Option<PathBuf>,
    spill_budget: u64,
    writer: Option<super::io::BinaryEdgeWriter>,
    num_shards: usize,
    /// Every shard below this index has been written to the file.
    next_shard: usize,
    /// Finished shards waiting for the frontier, keyed by index.
    pending: BTreeMap<usize, PendingShard>,
    /// Bytes of `PendingShard::Memory` runs currently held.
    deferred_bytes: u64,
    num_edges: u64,
}

impl BinaryFileSink {
    /// Sink writing to `path` (created/truncated at `begin`), with the
    /// default [spill budget](DEFAULT_SPILL_BUDGET) and spill files
    /// placed next to the output.
    pub fn create(path: impl AsRef<Path>) -> Self {
        BinaryFileSink {
            path: path.as_ref().to_path_buf(),
            spill_dir: None,
            spill_budget: DEFAULT_SPILL_BUDGET,
            writer: None,
            num_shards: 0,
            next_shard: 0,
            pending: BTreeMap::new(),
            deferred_bytes: 0,
            num_edges: 0,
        }
    }

    /// Directory for temp spill files (created if missing). Defaults to
    /// the output file's parent directory.
    pub fn spill_dir(mut self, dir: impl AsRef<Path>) -> Self {
        self.spill_dir = Some(dir.as_ref().to_path_buf());
        self
    }

    /// In-memory budget (bytes) for shards that finish ahead of the file
    /// frontier; beyond it they spill to disk. `0` forces every
    /// out-of-order shard to spill — the knob the forced-spill tests and
    /// the CI smoke leg use.
    pub fn spill_budget(mut self, bytes: u64) -> Self {
        self.spill_budget = bytes;
        self
    }

    /// Resolve (and create) the directory spill files go to.
    fn resolved_spill_dir(&self) -> io::Result<PathBuf> {
        let dir = match &self.spill_dir {
            Some(d) => d.clone(),
            None => match self.path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
                _ => PathBuf::from("."),
            },
        };
        std::fs::create_dir_all(&dir)?;
        Ok(dir)
    }

    /// Append one run to the file.
    fn write_run(&mut self, run: &[Edge]) -> io::Result<()> {
        let w = self
            .writer
            .as_mut()
            .ok_or_else(|| io::Error::other("write_run before begin"))?;
        w.write_edges(run)?;
        self.num_edges += run.len() as u64;
        Ok(())
    }

    /// Advance the frontier over every contiguous pending shard.
    fn drain_pending(&mut self) -> io::Result<()> {
        while let Some(shard) = self.pending.remove(&self.next_shard) {
            match shard {
                PendingShard::Memory(run) => {
                    self.deferred_bytes =
                        self.deferred_bytes.saturating_sub(run.len() as u64 * SPILL_EDGE_LEN);
                    self.write_run(&run)?;
                }
                PendingShard::Spilled(spill) => {
                    let writer = self
                        .writer
                        .as_mut()
                        .ok_or_else(|| io::Error::other("drain_pending before begin"))?;
                    let mut written = 0u64;
                    spill.for_each_chunk(SPILL_READ_CHUNK, |chunk| {
                        writer.write_edges(chunk)?;
                        written += chunk.len() as u64;
                        Ok(())
                    })?;
                    self.num_edges += written;
                    // Dropping the SpillRun removes the temp file.
                }
            }
            self.next_shard += 1;
        }
        Ok(())
    }
}

impl EdgeSink for BinaryFileSink {
    /// Number of edges written.
    type Output = u64;

    fn begin(&mut self, num_nodes: usize, num_shards: usize) -> io::Result<()> {
        self.writer = Some(super::io::BinaryEdgeWriter::create(&self.path, num_nodes)?);
        self.num_shards = num_shards.max(1);
        Ok(())
    }

    fn accept_shard(&mut self, index: usize, run: Vec<Edge>) -> io::Result<ShardDisposition> {
        if index >= self.num_shards {
            return Err(io::Error::other(format!("shard index {index} out of range")));
        }
        if index < self.next_shard || self.pending.contains_key(&index) {
            return Err(io::Error::other(format!("shard {index} delivered twice")));
        }
        if index == self.next_shard {
            self.write_run(&run)?;
            drop(run);
            self.next_shard += 1;
            self.drain_pending()?;
            return Ok(ShardDisposition::Streamed);
        }
        // Ahead of the frontier: defer in memory while the budget lasts,
        // spill to disk past it.
        let bytes = run.len() as u64 * SPILL_EDGE_LEN;
        if self.deferred_bytes + bytes <= self.spill_budget {
            self.deferred_bytes += bytes;
            self.pending.insert(index, PendingShard::Memory(run));
            return Ok(ShardDisposition::Deferred { bytes });
        }
        let dir = self.resolved_spill_dir()?;
        let mut writer = SpillWriter::create(unique_spill_path(&dir, &format!("shard{index}")))?;
        writer.write_edges(&run)?;
        drop(run);
        self.pending.insert(index, PendingShard::Spilled(writer.finish()?));
        Ok(ShardDisposition::Spilled { bytes })
    }

    fn finalize(mut self) -> io::Result<u64> {
        self.drain_pending()?;
        if self.next_shard < self.num_shards {
            return Err(io::Error::other(format!(
                "shard {} never delivered ({} of {} written)",
                self.next_shard, self.next_shard, self.num_shards
            )));
        }
        let w = self
            .writer
            .take()
            .ok_or_else(|| io::Error::other("BinaryFileSink finalized before begin"))?;
        w.finalize(self.num_edges)?;
        Ok(self.num_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn edges_of(pairs: &[(u32, u32)]) -> Vec<Edge> {
        pairs.to_vec()
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("magquilt_sink_test").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn shard_spec_partitions_sources() {
        let spec = ShardSpec::new(10, 3);
        assert_eq!(spec.num_shards(), 3);
        let shards: Vec<usize> = (0..10u32).map(|s| spec.shard_of(s)).collect();
        // Non-decreasing, starts at 0, ends at S-1, covers disjoint ranges.
        assert!(shards.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(shards[0], 0);
        assert_eq!(*shards.last().unwrap(), 2);
    }

    #[test]
    fn shard_spec_clamps_to_node_count() {
        // More shards than nodes would only add empty trailing shards;
        // the effective count is min(S, n) and is what num_shards reports.
        let spec = ShardSpec::new(2, 8);
        assert_eq!(spec.num_shards(), 2);
        assert_eq!(spec.shard_of(0), 0);
        assert_eq!(spec.shard_of(1), 1);
    }

    #[test]
    fn shard_spec_single_shard_takes_all() {
        let spec = ShardSpec::new(1000, 1);
        for s in [0u32, 17, 999] {
            assert_eq!(spec.shard_of(s), 0);
        }
    }

    #[test]
    fn shard_spec_checked_rejects_out_of_range_src() {
        let spec = ShardSpec::new(10, 3);
        assert_eq!(spec.checked_shard_of(9), Some(2));
        assert_eq!(spec.checked_shard_of(10), None);
        assert_eq!(spec.checked_shard_of(u32::MAX), None);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    #[cfg(debug_assertions)]
    fn shard_of_debug_asserts_bad_src() {
        // The old behavior silently clamped src >= n into the last shard,
        // masking upstream sampler bugs.
        ShardSpec::new(10, 3).shard_of(10);
    }

    #[test]
    fn merge_into_empty_run() {
        let mut run = Vec::new();
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(1, 2), (3, 4)])), 0);
        assert_eq!(run, edges_of(&[(1, 2), (3, 4)]));
    }

    #[test]
    fn merge_disjoint_appends() {
        let mut run = edges_of(&[(0, 1), (1, 0)]);
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(2, 0), (2, 1)])), 0);
        assert_eq!(run, edges_of(&[(0, 1), (1, 0), (2, 0), (2, 1)]));
    }

    #[test]
    fn merge_interleaved_with_duplicates() {
        let mut run = edges_of(&[(0, 1), (2, 2), (5, 0)]);
        let dropped = merge_sorted_into(&mut run, &edges_of(&[(0, 0), (2, 2), (5, 0), (7, 7)]));
        assert_eq!(dropped, 2);
        assert_eq!(run, edges_of(&[(0, 0), (0, 1), (2, 2), (5, 0), (7, 7)]));
    }

    #[test]
    fn merge_batch_entirely_before_run() {
        let mut run = edges_of(&[(5, 5), (6, 6)]);
        assert_eq!(merge_sorted_into(&mut run, &edges_of(&[(1, 1), (2, 2)])), 0);
        assert_eq!(run, edges_of(&[(1, 1), (2, 2), (5, 5), (6, 6)]));
    }

    #[test]
    fn merge_all_duplicates_collapses() {
        let mut run = edges_of(&[(1, 1), (2, 2)]);
        let dropped = merge_sorted_into(&mut run, &edges_of(&[(1, 1), (2, 2)]));
        assert_eq!(dropped, 2);
        assert_eq!(run, edges_of(&[(1, 1), (2, 2)]));
    }

    #[test]
    fn merge_randomized_matches_sort_dedup() {
        let mut rng = Rng::new(91);
        for case in 0..200 {
            let mut run: Vec<Edge> = (0..rng.below(40))
                .map(|_| (rng.below(16) as u32, rng.below(16) as u32))
                .collect();
            run.sort_unstable();
            run.dedup();
            let mut batch: Vec<Edge> = (0..rng.below(40))
                .map(|_| (rng.below(16) as u32, rng.below(16) as u32))
                .collect();
            batch.sort_unstable();
            batch.dedup();
            let mut want: Vec<Edge> = run.iter().chain(batch.iter()).copied().collect();
            want.sort_unstable();
            want.dedup();
            let before = run.len() + batch.len();
            let dropped = merge_sorted_into(&mut run, &batch);
            assert_eq!(run, want, "case {case}");
            assert_eq!(dropped, before - want.len(), "case {case}");
        }
    }

    #[test]
    fn shard_merger_tracks_stats_and_memory_bound() {
        let mut m = ShardMerger::new(3);
        m.absorb(edges_of(&[(4, 1), (0, 1), (4, 1)])); // one within-batch dup
        m.absorb(edges_of(&[(0, 1), (2, 2)])); // one cross-batch dup
        m.absorb(Vec::new()); // ignored
        let (run, stats) = m.finish();
        assert_eq!(run, edges_of(&[(0, 1), (2, 2), (4, 1)]));
        assert_eq!(stats.shard, 3);
        assert_eq!(stats.edges, 3);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.max_batch, 3);
        assert_eq!(stats.duplicates_dropped, 2);
        // The streaming-memory claim: never more resident than the final
        // run plus batch-sized merge overhead.
        assert!(stats.peak_resident <= stats.edges + 2 * stats.max_batch);
        // Spill columns are sink-side; mergers never set them.
        assert!(!stats.deferred);
        assert_eq!(stats.spill_runs, 0);
        assert_eq!(stats.spill_bytes, 0);
    }

    #[test]
    fn record_disposition_tracks_spill_columns() {
        let mut stats = ShardMergeStats::default();
        stats.record_disposition(ShardDisposition::Streamed);
        assert!(!stats.deferred);
        stats.record_disposition(ShardDisposition::Deferred { bytes: 64 });
        assert!(stats.deferred);
        assert_eq!(stats.spill_runs, 0);
        stats.record_disposition(ShardDisposition::Spilled { bytes: 128 });
        assert_eq!(stats.spill_runs, 1);
        assert_eq!(stats.spill_bytes, 128);
        let sum = summarize_spill(&[stats.clone(), ShardMergeStats::default()]);
        assert_eq!(sum.deferred_shards, 1);
        assert_eq!(sum.spilled_shards, 1);
        assert_eq!(sum.spill_runs, 1);
        assert_eq!(sum.spill_bytes, 128);
    }

    #[test]
    fn collect_sink_stitches_shards_in_index_order() {
        let mut sink = CollectSink::new();
        sink.begin(8, 2).unwrap();
        sink.accept_shard(0, edges_of(&[(0, 3), (1, 1)])).unwrap();
        sink.accept_shard(1, edges_of(&[(4, 0), (7, 7)])).unwrap();
        let g = sink.finalize().unwrap();
        assert_eq!(g.num_nodes(), 8);
        assert_eq!(g.edges(), &[(0, 3), (1, 1), (4, 0), (7, 7)]);
    }

    #[test]
    fn collect_sink_out_of_order_placement() {
        // Delivery order 2, 0, 1 must stitch identically to 0, 1, 2: a
        // frontier arrival appends at its offset immediately, an early
        // arrival waits in `pending` (deferred) until the frontier
        // reaches it.
        let shards =
            [edges_of(&[(0, 1)]), edges_of(&[(3, 0), (4, 4)]), edges_of(&[(6, 2), (7, 0)])];
        let mut sink = CollectSink::new();
        sink.begin(8, 3).unwrap();
        sink.begin_shard(2, shards[2].len()).unwrap();
        assert_eq!(
            sink.accept_shard(2, shards[2].clone()).unwrap(),
            ShardDisposition::Deferred { bytes: 16 }
        );
        sink.begin_shard(0, shards[0].len()).unwrap();
        assert_eq!(
            sink.accept_shard(0, shards[0].clone()).unwrap(),
            ShardDisposition::Streamed
        );
        sink.begin_shard(1, shards[1].len()).unwrap();
        assert_eq!(
            sink.accept_shard(1, shards[1].clone()).unwrap(),
            ShardDisposition::Streamed
        );
        let g = sink.finalize().unwrap();
        assert_eq!(g.edges(), &[(0, 1), (3, 0), (4, 4), (6, 2), (7, 0)]);
    }

    #[test]
    fn collect_sink_rejects_duplicate_and_missing_shards() {
        let mut sink = CollectSink::new();
        sink.begin(4, 2).unwrap();
        sink.accept_shard(0, edges_of(&[(0, 0)])).unwrap();
        assert!(sink.accept_shard(0, edges_of(&[(1, 1)])).is_err());
        assert!(sink.accept_shard(5, Vec::new()).is_err());
        // Shard 1 never arrives: finalize must fail, not return half a graph.
        assert!(sink.finalize().is_err());
    }

    #[test]
    fn counting_sink_matches_collected_degrees_any_order() {
        let shard0 = edges_of(&[(0, 1), (0, 2), (1, 1)]);
        let shard1 = edges_of(&[(2, 0), (3, 1)]);

        let mut collect = CollectSink::new();
        collect.begin(4, 2).unwrap();
        collect.accept_shard(0, shard0.clone()).unwrap();
        collect.accept_shard(1, shard1.clone()).unwrap();
        let g = collect.finalize().unwrap();

        // Counting consumes out of order for free — degree sums commute.
        let mut count = CountingSink::new();
        count.begin(4, 2).unwrap();
        assert_eq!(count.accept_shard(1, shard1).unwrap(), ShardDisposition::Streamed);
        assert_eq!(count.accept_shard(0, shard0).unwrap(), ShardDisposition::Streamed);
        let c = count.finalize().unwrap();

        assert_eq!(c.num_edges, g.num_edges() as u64);
        assert_eq!(c.self_loops, g.num_self_loops() as u64);
        assert_eq!(c.out_degrees, g.out_degrees());
        assert_eq!(c.in_degrees, g.in_degrees());
        assert_eq!(c.max_out_degree(), 2);
        assert_eq!(c.max_in_degree(), 3);
    }

    #[test]
    fn counting_sink_rejects_duplicate_shards() {
        let mut count = CountingSink::new();
        count.begin(4, 2).unwrap();
        count.accept_shard(1, edges_of(&[(0, 1)])).unwrap();
        assert!(count.accept_shard(1, edges_of(&[(0, 2)])).is_err());
        assert!(count.accept_shard(9, Vec::new()).is_err());
    }

    #[test]
    fn binary_file_sink_roundtrips_in_order() {
        let dir = tmp_dir("in_order");
        let path = dir.join("sink.bin");
        let mut sink = BinaryFileSink::create(&path);
        sink.begin(6, 2).unwrap();
        sink.accept_shard(0, edges_of(&[(0, 5), (2, 2)])).unwrap();
        sink.accept_shard(1, edges_of(&[(3, 0), (5, 4)])).unwrap();
        let written = sink.finalize().unwrap();
        assert_eq!(written, 4);
        let g = super::super::read_edge_list_binary(&path).unwrap();
        assert_eq!(g.num_nodes(), 6);
        assert_eq!(g.edges(), &[(0, 5), (2, 2), (3, 0), (5, 4)]);
    }

    #[test]
    fn binary_file_sink_defers_out_of_order_within_budget() {
        let dir = tmp_dir("deferred");
        let path = dir.join("sink.bin");
        let mut sink = BinaryFileSink::create(&path).spill_dir(&dir);
        sink.begin(6, 3).unwrap();
        // Shard 2 first: deferred in memory (default budget is plenty).
        assert_eq!(
            sink.accept_shard(2, edges_of(&[(4, 0), (5, 5)])).unwrap(),
            ShardDisposition::Deferred { bytes: 16 }
        );
        assert_eq!(
            sink.accept_shard(1, edges_of(&[(2, 1)])).unwrap(),
            ShardDisposition::Deferred { bytes: 8 }
        );
        // Shard 0 unblocks the frontier and drains 1 then 2 behind it.
        assert_eq!(
            sink.accept_shard(0, edges_of(&[(0, 1)])).unwrap(),
            ShardDisposition::Streamed
        );
        let written = sink.finalize().unwrap();
        assert_eq!(written, 4);
        let g = super::super::read_edge_list_binary(&path).unwrap();
        assert_eq!(g.edges(), &[(0, 1), (2, 1), (4, 0), (5, 5)]);
    }

    #[test]
    fn binary_file_sink_spills_over_budget_and_cleans_up() {
        // The acceptance shape: the highest shard finishes first with a
        // zero budget — it must spill, the file must still come out
        // bit-for-bit in index order, and the spill temp must be gone.
        let dir = tmp_dir("forced_spill");
        let spill_dir = dir.join("spill");
        let path = dir.join("sink.bin");
        let mut sink = BinaryFileSink::create(&path).spill_dir(&spill_dir).spill_budget(0);
        sink.begin(8, 3).unwrap();
        let d = sink.accept_shard(2, edges_of(&[(6, 1), (7, 3)])).unwrap();
        assert_eq!(d, ShardDisposition::Spilled { bytes: 16 });
        assert_eq!(std::fs::read_dir(&spill_dir).unwrap().count(), 1, "spill file exists");
        assert_eq!(
            sink.accept_shard(1, edges_of(&[(3, 3)])).unwrap(),
            ShardDisposition::Spilled { bytes: 8 }
        );
        assert_eq!(
            sink.accept_shard(0, edges_of(&[(0, 2), (1, 0)])).unwrap(),
            ShardDisposition::Streamed
        );
        let written = sink.finalize().unwrap();
        assert_eq!(written, 5);
        let g = super::super::read_edge_list_binary(&path).unwrap();
        assert_eq!(g.edges(), &[(0, 2), (1, 0), (3, 3), (6, 1), (7, 3)]);
        assert_eq!(std::fs::read_dir(&spill_dir).unwrap().count(), 0, "spill files removed");
    }

    #[test]
    fn binary_file_sink_mixed_defer_and_spill() {
        // Budget fits exactly one small shard: the second out-of-order
        // arrival goes to disk while the first stays in memory.
        let dir = tmp_dir("mixed");
        let path = dir.join("sink.bin");
        let mut sink = BinaryFileSink::create(&path).spill_dir(&dir).spill_budget(8);
        sink.begin(8, 4).unwrap();
        assert_eq!(
            sink.accept_shard(1, edges_of(&[(2, 2)])).unwrap(),
            ShardDisposition::Deferred { bytes: 8 }
        );
        assert_eq!(
            sink.accept_shard(3, edges_of(&[(7, 7)])).unwrap(),
            ShardDisposition::Spilled { bytes: 8 }
        );
        assert_eq!(
            sink.accept_shard(2, edges_of(&[(4, 1), (5, 0)])).unwrap(),
            ShardDisposition::Spilled { bytes: 16 }
        );
        assert_eq!(
            sink.accept_shard(0, edges_of(&[(0, 0)])).unwrap(),
            ShardDisposition::Streamed
        );
        let written = sink.finalize().unwrap();
        assert_eq!(written, 5);
        let g = super::super::read_edge_list_binary(&path).unwrap();
        assert_eq!(g.edges(), &[(0, 0), (2, 2), (4, 1), (5, 0), (7, 7)]);
    }

    #[test]
    fn binary_file_sink_rejects_duplicate_and_missing_shards() {
        let dir = tmp_dir("protocol");
        let mut sink = BinaryFileSink::create(dir.join("dup.bin"));
        sink.begin(4, 3).unwrap();
        sink.accept_shard(0, edges_of(&[(0, 1)])).unwrap();
        assert!(sink.accept_shard(0, edges_of(&[(1, 1)])).is_err(), "re-delivery at frontier");
        sink.accept_shard(2, edges_of(&[(3, 1)])).unwrap();
        assert!(sink.accept_shard(2, edges_of(&[(3, 2)])).is_err(), "re-delivery of pending");
        assert!(sink.accept_shard(7, Vec::new()).is_err(), "index out of range");
        // Shard 1 missing: finalize must fail, and the unfinalized file
        // must not read back as a valid graph.
        assert!(sink.finalize().is_err());
        assert!(super::super::read_edge_list_binary(&dir.join("dup.bin")).is_err());
    }
}
