//! Graph substrate: edge lists, CSR, algorithms, IO.
//!
//! The samplers produce directed graphs as [`EdgeList`]s (node ids are
//! `u32`, supporting the paper's largest runs of n = 2^23). Analyses
//! (degree distributions, SCC fraction, clustering) run on the compressed
//! [`Csr`] form.

mod algorithms;
mod csr;
mod edgelist;
mod io;
mod sink;
mod spill;

pub use algorithms::{clustering_coefficient, largest_scc_size, largest_wcc_size, scc_sizes};
pub use csr::Csr;
pub use edgelist::EdgeList;
pub use io::{read_binary_body, read_binary_header, read_edge_list_binary, read_edge_list_text,
             write_edge_list_binary, write_edge_list_text, BinaryEdgeWriter, BinaryHeader,
             BINARY_MAGIC};
pub use sink::{summarize_spill, BinaryFileSink, CollectSink, CountingSink, DegreeCounts,
               EdgeSink, ShardDisposition, ShardMergeStats, ShardMerger, ShardSpec,
               SpillSummary, DEFAULT_SPILL_BUDGET};
pub use spill::{run_nonce, unique_spill_path, unique_temp_path, write_atomic, SpillRun,
                SpillWriter};

/// Node identifier. u32 covers n up to 4.29e9, well past the paper's 2^23.
pub type NodeId = u32;

/// A directed edge (source, target).
pub type Edge = (NodeId, NodeId);
