//! `maglint`: the determinism-invariant static-analysis pass.
//!
//! Every guarantee this crate makes — samples that are bit-for-bit stable
//! across `--workers`/`--setup-threads`/`--merge-threads`, a distributed
//! merge byte-identical to the single-process sink — rests on conventions
//! that the type system cannot see: unique RNG fork tags, never letting a
//! hash map's iteration order reach the output, keeping wall-clock state
//! out of output-determining modules, and deciding the hash fate of every
//! plan field. This module enforces those conventions as a line-based
//! static pass over `rust/src`, run by `cargo run --bin maglint`, by the
//! `lint` CI job, and by the self-run test below.
//!
//! The seven rules (see `docs/determinism.md` for the rationale and the
//! annotation syntax):
//!
//! 1. **RNG stream registry** — fork tags live in `rust/src/rngtags.rs`
//!    as named constants; tag values must be pairwise distinct, and a raw
//!    hex literal inside a `fork(...)` call anywhere else is an error.
//! 2. **Order leak** — `.keys()`/`.values()`/`.drain()` (and `.iter()` on
//!    a receiver declared as `FastMap`/`FastSet`/`HashMap`/`HashSet`) in
//!    non-test code is an error unless the line carries
//!    `// lint: order-ok(<reason>)` or the receiver is an ordered
//!    (`BTreeMap`/`BTreeSet`) container.
//! 3. **Nondeterminism source** — `SystemTime::now`, `Instant::now`,
//!    `available_parallelism` and `std::env` are forbidden inside the
//!    output-determining modules (`kpgm/`, `quilt/`, `magm/`,
//!    `dist/plan.rs`) unless annotated `// lint: time-ok(...)` /
//!    `// lint: env-ok(...)`.
//! 4. **Panic path** — `unwrap()`/`expect(`/`panic!` outside `#[cfg(test)]`
//!    in the I/O-facing modules (`graph/io.rs`, `graph/sink.rs`,
//!    `graph/spill.rs`, `dist/`) must be annotated
//!    `// lint: panic-ok(<reason>)` or converted to propagated errors.
//! 5. **Plan-hash drift** — every `ShardPlan` field must be referenced by
//!    `fn canonical` or named in `HASH_EXEMPT`, and every `RunSpec` field
//!    must appear in exactly one of `RUNSPEC_HASHED`/`RUNSPEC_EXEMPT`
//!    (both in `dist/plan.rs`), so adding a config field without deciding
//!    its hash fate fails the lint. The same tripwire covers the setup
//!    artifact's identity (`setup/mod.rs`): every `ArtifactHeader` field
//!    must be hashed by its `fn canonical` or named in `ART_HASH_EXEMPT`,
//!    and the exhaustive-destructuring witness
//!    (`artifact_hash_disposition_witness`) must name every field.
//! 6. **Fault hook** — the fault-injection machinery (`FaultPlan`,
//!    `inject_fault`, `crash_point`) is confined to the I/O and driver
//!    layers; a reference inside an output-determining module (the rule-3
//!    scope) is an error unless annotated `// lint: fault-ok(<reason>)`,
//!    so an injected crash can change *when* bytes hit disk but never
//!    *which* bytes the sampler derives.
//! 7. **Trace sink** — telemetry is write-only, in both directions: the
//!    trace machinery (`TraceWriter`, `TraceHandle`, `ProgressState`,
//!    `trace::`) may not be named inside an output-determining module
//!    (the rule-3 scope) unless annotated `// lint: trace-ok(<reason>)`,
//!    and the sources under `trace/` may not name the stream-fork or
//!    hashing machinery (`Rng`, `.fork(`, `fnv1a`) at all — so a trace
//!    value can never feed a stream fork, a hash, or any
//!    output-determining state (see `docs/observability.md`).
//!
//! The pass is deliberately line-based (zero new dependencies, no syntax
//! tree): string literals and `//` comments are stripped before matching,
//! the test region of a file starts at a `#[cfg(test)]` that gates a
//! `mod`, and receivers are resolved by walking identifier characters —
//! heuristics that are exact on this codebase and conservative (annotate
//! to override) on code they cannot see through.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

/// Which invariant a finding violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Raw hex literal inside a `fork(...)` call outside the registry.
    RawForkTag,
    /// Two registry constants share a tag value.
    DuplicateForkTag,
    /// Malformed registry entry (not a parseable `u64` constant).
    Registry,
    /// Unordered-container iteration order can reach the output.
    OrderLeak,
    /// Wall-clock / environment state in an output-determining module.
    NondetSource,
    /// Panic path in an I/O-facing module.
    PanicPath,
    /// Plan/run field with an undecided hash fate.
    HashDrift,
    /// Fault-injection hook in an output-determining module.
    FaultHook,
    /// Telemetry flowing against the write-only trace boundary.
    TraceSink,
}

impl Rule {
    /// Stable short name used in output and asserted by the fixture tests.
    pub fn name(&self) -> &'static str {
        match self {
            Rule::RawForkTag => "raw-fork-tag",
            Rule::DuplicateForkTag => "duplicate-fork-tag",
            Rule::Registry => "registry",
            Rule::OrderLeak => "order-leak",
            Rule::NondetSource => "nondet-source",
            Rule::PanicPath => "panic-path",
            Rule::HashDrift => "hash-drift",
            Rule::FaultHook => "fault-hook",
            Rule::TraceSink => "trace-sink",
        }
    }
}

/// One lint violation, anchored to a file and 1-based line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule violated.
    pub rule: Rule,
    /// Path relative to `rust/src`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description with the fix direction.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.name(), self.message)
    }
}

/// Does the raw line carry a `// lint: <kind>-ok(...)` annotation?
fn annotated(raw_line: &str, kind: &str) -> bool {
    let needle = format!("lint: {kind}-ok(");
    raw_line.contains(&needle)
}

/// Strip string literals, char literals, and `//` comments so pattern
/// matching sees only code. Stripped spans are replaced by spaces to keep
/// column positions meaningful.
fn sanitize(line: &str) -> String {
    let chars: Vec<char> = line.chars().collect();
    let mut out = String::with_capacity(line.len());
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < chars.len() {
                if chars[i] == '\\' {
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            out.push(' ');
            continue;
        }
        if c == '\'' {
            // Char literal ('x', '\n') vs lifetime ('a with no closing
            // quote): consume only when a closing quote is adjacent.
            if i + 3 < chars.len() && chars[i + 1] == '\\' && chars[i + 3] == '\'' {
                out.push_str("    ");
                i += 4;
                continue;
            }
            if i + 2 < chars.len() && chars[i + 2] == '\'' {
                out.push_str("   ");
                i += 3;
                continue;
            }
            out.push(c);
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < chars.len() && chars[i + 1] == '/' {
            break;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// 1-based line numbers (exclusive start) of each file's test region: the
/// first `#[cfg(test)]` attribute that gates a `mod` opens it and it runs
/// to end of file (test modules sit at the bottom of every file here). A
/// `#[cfg(test)]` on a single non-`mod` item does NOT open the region, so
/// code between such an item and the real test module stays linted.
fn test_region_start(lines: &[&str]) -> usize {
    for (i, line) in lines.iter().enumerate() {
        if line.trim_start().starts_with("#[cfg(test)]") {
            for follow in lines.iter().skip(i + 1) {
                let t = follow.trim_start();
                if t.is_empty() || t.starts_with("#[") {
                    continue;
                }
                if t.starts_with("mod ") || t.starts_with("pub mod ") {
                    return i;
                }
                break;
            }
        }
    }
    lines.len()
}

/// Identifier (walking `[A-Za-z0-9_]`) ending exactly at byte `end` of
/// `code`, or `None` if the preceding token is not a plain identifier.
fn ident_ending_at(code: &str, end: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut start = end;
    while start > 0 {
        let b = bytes[start - 1] as char;
        if b.is_ascii_alphanumeric() || b == '_' {
            start -= 1;
        } else {
            break;
        }
    }
    if start == end {
        return None;
    }
    let ident = &code[start..end];
    if ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(ident.to_string())
}

/// Container kinds the order-leak rule tracks.
const UNORDERED_TYPES: &[&str] = &["FastMap", "FastSet", "HashMap", "HashSet"];
const ORDERED_TYPES: &[&str] = &["BTreeMap", "BTreeSet"];
const UNORDERED_CTORS: &[&str] = &[
    "FastMap::",
    "FastSet::",
    "HashMap::new",
    "HashSet::new",
    "fast_map_with_capacity",
    "fast_set_with_capacity",
];
const ORDERED_CTORS: &[&str] = &["BTreeMap::new", "BTreeSet::new"];

/// Is `seg` (the text between a declaration's `:` and its type name) a
/// plain type position — optional `&`/`mut` and path segments only? This
/// rejects nested positions like `: Vec<FastMap<...>>`, whose *outer*
/// container is ordered.
fn plain_type_position(seg: &str) -> bool {
    let mut rest = seg.trim();
    loop {
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix('&') {
            rest = r;
            continue;
        }
        if let Some(r) = rest.strip_prefix("mut ") {
            rest = r;
            continue;
        }
        break;
    }
    // Remaining must be zero or more `ident::` path segments.
    while let Some(pos) = rest.find("::") {
        let seg_name = &rest[..pos];
        if !seg_name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return false;
        }
        rest = &rest[pos + 2..];
    }
    rest.trim().is_empty()
}

/// Find identifiers declared on this line with one of `types` as their
/// outermost container: `name: [&][path::]T<...>` or
/// `[let [mut]] name = [path::]ctor...`.
fn declared_idents(code: &str, types: &[&str], ctors: &[&str]) -> Vec<String> {
    let mut out = Vec::new();
    for t in types {
        let pat = format!("{t}<");
        let mut from = 0;
        while let Some(p) = code[from..].find(&pat) {
            let abs = from + p;
            from = abs + pat.len();
            // Word boundary before the type name.
            if ident_ending_at(code, abs).is_some() {
                continue;
            }
            let before = &code[..abs];
            // Last single `:` (not `::`) before the type.
            let bytes = before.as_bytes();
            let mut colon = None;
            let mut k = 0;
            while k < bytes.len() {
                if bytes[k] == b':' {
                    if k + 1 < bytes.len() && bytes[k + 1] == b':' {
                        k += 2;
                        continue;
                    }
                    colon = Some(k);
                }
                k += 1;
            }
            let Some(cpos) = colon else { continue };
            if !plain_type_position(&before[cpos + 1..]) {
                continue;
            }
            if let Some(name) = ident_ending_at(before, cpos) {
                out.push(name);
            }
        }
    }
    for ctor in ctors {
        let pat = format!("= {ctor}");
        if let Some(p) = code.find(&pat) {
            let before = code[..p].trim_end();
            if let Some(name) = ident_ending_at(before, before.len()) {
                out.push(name);
            }
        }
    }
    out
}

/// Methods that expose a map/set's internal order directly.
const KEY_METHODS: &[&str] =
    &[".keys()", ".values()", ".values_mut()", ".into_keys()", ".into_values()", ".drain("];
/// Methods that expose order only when the receiver is a tracked
/// unordered container (otherwise they are ordinary slice/Vec iteration).
const ITER_METHODS: &[&str] = &[".iter()", ".iter_mut()", ".into_iter()"];

/// Is `relpath` (relative to `rust/src`) inside the output-determining
/// scope of the nondeterminism-source rule?
fn in_nondet_scope(relpath: &str) -> bool {
    relpath.starts_with("kpgm/")
        || relpath.starts_with("quilt/")
        || relpath.starts_with("magm/")
        || relpath == "dist/plan.rs"
}

/// Is `relpath` inside the panic-path rule's I/O-facing scope?
fn in_panic_scope(relpath: &str) -> bool {
    relpath == "graph/io.rs"
        || relpath == "graph/sink.rs"
        || relpath == "graph/spill.rs"
        || relpath.starts_with("dist/")
}

/// Is `relpath` inside the telemetry layer itself (rule 7's write-only
/// side)?
fn in_trace_scope(relpath: &str) -> bool {
    relpath.starts_with("trace/")
}

const NONDET_PATTERNS: &[&str] =
    &["SystemTime::now", "Instant::now", "available_parallelism", "std::env"];
const PANIC_PATTERNS: &[&str] = &[".unwrap()", ".expect(", "panic!(", "unreachable!("];
/// Names of the fault-injection machinery (rule 6). Kept in sync with
/// `dist/fault.rs` — the lint is what proves the hooks never migrate into
/// the sampling layers.
const FAULT_PATTERNS: &[&str] = &["FaultPlan", "inject_fault", "crash_point"];
/// Names of the telemetry machinery (rule 7, outward direction): an
/// output-determining module naming these could route trace state back
/// into the sample. Kept in sync with `trace/mod.rs`.
const TRACE_MACHINERY: &[&str] = &["TraceWriter", "TraceHandle", "ProgressState", "trace::"];
/// Stream-fork / hashing machinery banned inside `trace/` itself
/// (rule 7, inward direction): trace code that cannot even name these
/// cannot fold telemetry into anything output-determining.
const TRACE_FORBIDDEN: &[&str] = &["Rng", ".fork(", "fnv1a"];

/// Lint one source file (rules 1–4). `relpath` is relative to `rust/src`
/// and selects the module-scoped rules; the registry file itself is
/// linted with [`lint_registry`] instead.
pub fn lint_source(relpath: &str, source: &str) -> Vec<Finding> {
    let lines: Vec<&str> = source.lines().collect();
    let test_start = test_region_start(&lines);
    let mut findings = Vec::new();
    let mut unordered: Vec<String> = Vec::new();
    let mut ordered: Vec<String> = Vec::new();
    let fork_call = ".fork(";
    let hex_prefix = "0x";

    for (idx, raw) in lines.iter().enumerate() {
        let code = sanitize(raw);
        for name in declared_idents(&code, UNORDERED_TYPES, UNORDERED_CTORS) {
            if !unordered.contains(&name) {
                unordered.push(name);
            }
        }
        for name in declared_idents(&code, ORDERED_TYPES, ORDERED_CTORS) {
            if !ordered.contains(&name) {
                ordered.push(name);
            }
        }
        if idx >= test_start {
            continue;
        }
        let lineno = idx + 1;

        // Rule 1: raw hex fork tags outside the registry.
        if let Some(p) = code.find(fork_call) {
            if code[p..].contains(hex_prefix) {
                findings.push(Finding {
                    rule: Rule::RawForkTag,
                    file: relpath.to_string(),
                    line: lineno,
                    message: "raw hex literal in fork(...); name the stream in \
                              rngtags.rs and fork the constant"
                        .to_string(),
                });
            }
        }

        // Rule 2: order leaks.
        if !annotated(raw, "order") {
            for m in KEY_METHODS {
                let mut from = 0;
                while let Some(p) = code[from..].find(m) {
                    let abs = from + p;
                    from = abs + m.len();
                    let recv = ident_ending_at(&code, abs);
                    let is_ordered =
                        recv.as_ref().map(|r| ordered.contains(r)).unwrap_or(false);
                    if !is_ordered {
                        findings.push(Finding {
                            rule: Rule::OrderLeak,
                            file: relpath.to_string(),
                            line: lineno,
                            message: format!(
                                "{m} on an unordered (or unresolvable) container; sort the \
                                 result or annotate the line with lint: order-ok(reason)"
                            ),
                        });
                    }
                }
            }
            for m in ITER_METHODS {
                let mut from = 0;
                while let Some(p) = code[from..].find(m) {
                    let abs = from + p;
                    from = abs + m.len();
                    if let Some(recv) = ident_ending_at(&code, abs) {
                        if unordered.contains(&recv) && !ordered.contains(&recv) {
                            findings.push(Finding {
                                rule: Rule::OrderLeak,
                                file: relpath.to_string(),
                                line: lineno,
                                message: format!(
                                    "{m} on unordered container `{recv}`; sort the result \
                                     or annotate with lint: order-ok(reason)"
                                ),
                            });
                        }
                    }
                }
            }
            // `for x in &map` / `in &mut map` / `in &self.map` forms.
            let mut from = 0;
            while let Some(p) = code[from..].find(" in &") {
                let abs = from + p + " in &".len();
                from = abs;
                let mut rest = &code[abs..];
                if let Some(r) = rest.strip_prefix("mut ") {
                    rest = r;
                }
                if let Some(r) = rest.strip_prefix("self.") {
                    rest = r;
                }
                let name: String =
                    rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
                if !name.is_empty() && unordered.contains(&name) && !ordered.contains(&name) {
                    findings.push(Finding {
                        rule: Rule::OrderLeak,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "iteration over unordered container `{name}`; sort the result \
                             or annotate with lint: order-ok(reason)"
                        ),
                    });
                }
            }
        }

        // Rule 3: nondeterminism sources in output-determining modules.
        if in_nondet_scope(relpath) && !annotated(raw, "time") && !annotated(raw, "env") {
            for pat in NONDET_PATTERNS {
                if code.contains(pat) {
                    findings.push(Finding {
                        rule: Rule::NondetSource,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "{pat} in an output-determining module; derive from the plan/seed \
                             or annotate with lint: time-ok(...) / lint: env-ok(...)"
                        ),
                    });
                }
            }
        }

        // Rule 6: fault-injection hooks in output-determining modules.
        if in_nondet_scope(relpath) && !annotated(raw, "fault") {
            for pat in FAULT_PATTERNS {
                if code.contains(pat) {
                    findings.push(Finding {
                        rule: Rule::FaultHook,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "{pat} referenced in an output-determining module; fault injection \
                             belongs to the I/O/driver layers (dist/fault.rs) — move it or \
                             annotate with lint: fault-ok(reason)"
                        ),
                    });
                }
            }
        }

        // Rule 7: the trace boundary is write-only, checked from both
        // sides. Outward: output-determining modules may not name the
        // telemetry machinery (a sampler that can read a TraceHandle can
        // fold observability back into the sample). Inward: trace/ may
        // not name the stream-fork or hashing machinery at all.
        if in_nondet_scope(relpath) && !annotated(raw, "trace") {
            for pat in TRACE_MACHINERY {
                if code.contains(pat) {
                    findings.push(Finding {
                        rule: Rule::TraceSink,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "{pat} referenced in an output-determining module; telemetry is \
                             write-only — emit from the coordinator/driver layers or annotate \
                             with lint: trace-ok(reason)"
                        ),
                    });
                }
            }
        }
        if in_trace_scope(relpath) && !annotated(raw, "trace") {
            for pat in TRACE_FORBIDDEN {
                if code.contains(pat) {
                    findings.push(Finding {
                        rule: Rule::TraceSink,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "{pat} referenced inside trace/; the telemetry layer may not \
                             touch RNG streams or output hashing — move the computation out \
                             or annotate with lint: trace-ok(reason)"
                        ),
                    });
                }
            }
        }

        // Rule 4: panic paths in I/O-facing modules.
        if in_panic_scope(relpath) && !annotated(raw, "panic") {
            for pat in PANIC_PATTERNS {
                if code.contains(pat) {
                    findings.push(Finding {
                        rule: Rule::PanicPath,
                        file: relpath.to_string(),
                        line: lineno,
                        message: format!(
                            "{pat} outside #[cfg(test)]; propagate an error or annotate \
                             with lint: panic-ok(reason)"
                        ),
                    });
                }
            }
        }
    }
    findings
}

/// One parsed registry constant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegistryTag {
    /// Constant name.
    pub name: String,
    /// Tag value.
    pub value: u64,
    /// 1-based line of the declaration.
    pub line: usize,
}

/// Parse `pub const NAME: u64 = <literal>;` declarations out of the
/// registry source.
pub fn parse_registry(source: &str) -> (Vec<RegistryTag>, Vec<(usize, String)>) {
    let mut tags = Vec::new();
    let mut errors = Vec::new();
    for (idx, raw) in source.lines().enumerate() {
        let t = raw.trim_start();
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let Some((name, after)) = rest.split_once(':') else { continue };
        let after = after.trim_start();
        if !after.starts_with("u64") {
            continue;
        }
        let Some((_, value_part)) = after.split_once('=') else {
            errors.push((idx + 1, format!("constant {} has no value", name.trim())));
            continue;
        };
        let value_text = value_part.trim().trim_end_matches(';').trim().replace('_', "");
        let parsed = if let Some(hex) = value_text.strip_prefix("0x") {
            u64::from_str_radix(hex, 16)
        } else {
            value_text.parse::<u64>()
        };
        match parsed {
            Ok(value) => {
                tags.push(RegistryTag { name: name.trim().to_string(), value, line: idx + 1 })
            }
            Err(_) => errors.push((
                idx + 1,
                format!("constant {} is not a literal u64 tag: {value_text:?}", name.trim()),
            )),
        }
    }
    (tags, errors)
}

/// Lint the registry file: every `u64` constant must parse and tag values
/// must be pairwise distinct.
pub fn lint_registry(relpath: &str, source: &str) -> Vec<Finding> {
    let (tags, errors) = parse_registry(source);
    let mut findings: Vec<Finding> = errors
        .into_iter()
        .map(|(line, message)| Finding {
            rule: Rule::Registry,
            file: relpath.to_string(),
            line,
            message,
        })
        .collect();
    if tags.is_empty() {
        findings.push(Finding {
            rule: Rule::Registry,
            file: relpath.to_string(),
            line: 1,
            message: "registry declares no fork-tag constants".to_string(),
        });
    }
    for (i, a) in tags.iter().enumerate() {
        for b in &tags[i + 1..] {
            if a.value == b.value {
                findings.push(Finding {
                    rule: Rule::DuplicateForkTag,
                    file: relpath.to_string(),
                    line: b.line,
                    message: format!(
                        "tag {} duplicates the value {:#x} of {} (line {}); streams sharing \
                         a tag must share one constant",
                        b.name, b.value, a.name, a.line
                    ),
                });
            }
        }
    }
    findings
}

/// Field names of `pub struct <name> { ... }` in `source`, with 1-based
/// declaration lines.
fn struct_fields(source: &str, name: &str) -> Vec<(String, usize)> {
    let header = format!("pub struct {name} {{");
    let mut fields = Vec::new();
    let mut inside = false;
    for (idx, raw) in source.lines().enumerate() {
        let t = raw.trim();
        if !inside {
            if t.starts_with(&header) {
                inside = true;
            }
            continue;
        }
        if t == "}" {
            break;
        }
        if t.starts_with("///") || t.starts_with("#[") || t.is_empty() {
            continue;
        }
        let decl = t.strip_prefix("pub ").unwrap_or(t);
        if let Some((field, _)) = decl.split_once(':') {
            let f = field.trim();
            if f.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') && !f.is_empty() {
                fields.push((f.to_string(), idx + 1));
            }
        }
    }
    fields
}

/// Extract the body of `fn <name>` (brace-balanced from its first `{`).
fn fn_body<'a>(source: &'a str, name: &str) -> Option<(String, usize)> {
    let needle = format!("fn {name}(");
    let lines: Vec<&str> = source.lines().collect();
    let start = lines.iter().position(|l| l.contains(&needle))?;
    let mut depth = 0i64;
    let mut opened = false;
    let mut body = String::new();
    for line in lines.iter().skip(start) {
        let code = sanitize(line);
        for c in code.chars() {
            if c == '{' {
                depth += 1;
                opened = true;
            }
            if c == '}' {
                depth -= 1;
            }
        }
        body.push_str(&code);
        body.push('\n');
        if opened && depth <= 0 {
            break;
        }
    }
    Some((body, start + 1))
}

/// Quoted strings of the `const <name>` array starting at its declaration
/// line and running to the closing `]`.
fn const_string_list(source: &str, name: &str) -> Option<(Vec<String>, usize)> {
    let needle = format!("const {name}:");
    let lines: Vec<&str> = source.lines().collect();
    let start = lines.iter().position(|l| l.contains(&needle))?;
    let mut out = Vec::new();
    // Scan only after the `=`: the `&[&str]` type annotation on the
    // declaration line contains a `]` that must not end the list.
    let mut past_eq = false;
    for line in lines.iter().skip(start) {
        let mut rest: &str = line;
        if !past_eq {
            let Some(p) = rest.find('=') else { continue };
            past_eq = true;
            rest = &rest[p + 1..];
        }
        let close = rest.contains(']');
        while let Some(p) = rest.find('"') {
            let after = &rest[p + 1..];
            let Some(q) = after.find('"') else { break };
            out.push(after[..q].to_string());
            rest = &after[q + 1..];
        }
        if close {
            break;
        }
    }
    Some((out, start + 1))
}

/// Does `body` reference `self.<field>` as a whole identifier?
fn references_field(body: &str, field: &str) -> bool {
    let needle = format!("self.{field}");
    let mut from = 0;
    while let Some(p) = body[from..].find(&needle) {
        let end = from + p + needle.len();
        let next = body[end..].chars().next();
        if !next.is_some_and(|c| c.is_ascii_alphanumeric() || c == '_') {
            return true;
        }
        from = end;
    }
    false
}

/// Rule 5: the plan-hash drift tripwire. `plan_src` must declare
/// `ShardPlan`, `fn canonical`, `HASH_EXEMPT`, `RUNSPEC_HASHED` and
/// `RUNSPEC_EXEMPT`; `spec_src` declares `RunSpec`. Every field must have
/// exactly one hash fate, and the fate lists must not go stale.
pub fn check_plan_hash(
    plan_path: &str,
    plan_src: &str,
    spec_path: &str,
    spec_src: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    let missing = |line: usize, message: String| Finding {
        rule: Rule::HashDrift,
        file: plan_path.to_string(),
        line,
        message,
    };

    let plan_fields = struct_fields(plan_src, "ShardPlan");
    if plan_fields.is_empty() {
        findings.push(missing(1, "no `pub struct ShardPlan` found".to_string()));
        return findings;
    }
    let Some((canonical, _)) = fn_body(plan_src, "canonical") else {
        findings.push(missing(1, "no `fn canonical` found to hash ShardPlan".to_string()));
        return findings;
    };
    let Some((exempt, exempt_line)) = const_string_list(plan_src, "HASH_EXEMPT") else {
        findings.push(missing(1, "no `HASH_EXEMPT` list found".to_string()));
        return findings;
    };
    for (field, line) in &plan_fields {
        let hashed = references_field(&canonical, field);
        let exempted = exempt.iter().any(|e| e == field);
        if hashed && exempted {
            findings.push(missing(
                *line,
                format!("ShardPlan.{field} is both hashed in canonical() and HASH_EXEMPT"),
            ));
        }
        if !hashed && !exempted {
            findings.push(missing(
                *line,
                format!(
                    "ShardPlan.{field} is neither hashed in canonical() nor named in \
                     HASH_EXEMPT; decide its hash fate"
                ),
            ));
        }
    }
    for entry in &exempt {
        if !plan_fields.iter().any(|(f, _)| f == entry) {
            findings.push(missing(
                exempt_line,
                format!("HASH_EXEMPT names {entry:?}, which is not a ShardPlan field"),
            ));
        }
    }

    let spec_fields = struct_fields(spec_src, "RunSpec");
    if spec_fields.is_empty() {
        findings.push(Finding {
            rule: Rule::HashDrift,
            file: spec_path.to_string(),
            line: 1,
            message: "no `pub struct RunSpec` found".to_string(),
        });
        return findings;
    }
    let hashed_list = const_string_list(plan_src, "RUNSPEC_HASHED");
    let exempt_list = const_string_list(plan_src, "RUNSPEC_EXEMPT");
    let (Some((run_hashed, rh_line)), Some((run_exempt, re_line))) = (hashed_list, exempt_list)
    else {
        findings.push(missing(
            1,
            "RUNSPEC_HASHED / RUNSPEC_EXEMPT lists not found; every RunSpec field needs a \
             declared hash fate"
                .to_string(),
        ));
        return findings;
    };
    for (field, _) in &spec_fields {
        let h = run_hashed.iter().any(|e| e == field);
        let e = run_exempt.iter().any(|e| e == field);
        if h && e {
            findings.push(missing(
                rh_line,
                format!("RunSpec.{field} appears in both RUNSPEC_HASHED and RUNSPEC_EXEMPT"),
            ));
        }
        if !h && !e {
            findings.push(Finding {
                rule: Rule::HashDrift,
                file: spec_path.to_string(),
                line: spec_fields.iter().find(|(f, _)| f == field).map(|(_, l)| *l).unwrap_or(1),
                message: format!(
                    "RunSpec.{field} is in neither RUNSPEC_HASHED nor RUNSPEC_EXEMPT \
                     (dist/plan.rs); decide whether it determines the output"
                ),
            });
        }
    }
    for entry in run_hashed.iter().chain(run_exempt.iter()) {
        if !spec_fields.iter().any(|(f, _)| f == entry) {
            findings.push(missing(
                if run_hashed.contains(entry) { rh_line } else { re_line },
                format!("RunSpec fate list names {entry:?}, which is not a RunSpec field"),
            ));
        }
    }
    findings
}

/// Rule 5 (artifact leg): the setup-artifact hash-drift tripwire.
/// `setup_src` must declare `ArtifactHeader`, its `fn canonical`,
/// `ART_HASH_EXEMPT`, and the `artifact_hash_disposition_witness`
/// destructuring witness. Every header field needs exactly one hash
/// fate, the exempt list must not go stale, and the witness must name
/// every field (its destructuring is what makes a new field a compile
/// error until its fate is decided).
pub fn check_artifact_hash(setup_path: &str, setup_src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let missing = |line: usize, message: String| Finding {
        rule: Rule::HashDrift,
        file: setup_path.to_string(),
        line,
        message,
    };

    let fields = struct_fields(setup_src, "ArtifactHeader");
    if fields.is_empty() {
        findings.push(missing(1, "no `pub struct ArtifactHeader` found".to_string()));
        return findings;
    }
    let Some((canonical, _)) = fn_body(setup_src, "canonical") else {
        findings.push(missing(1, "no `fn canonical` found to hash ArtifactHeader".to_string()));
        return findings;
    };
    let Some((exempt, exempt_line)) = const_string_list(setup_src, "ART_HASH_EXEMPT") else {
        findings.push(missing(1, "no `ART_HASH_EXEMPT` list found".to_string()));
        return findings;
    };
    for (field, line) in &fields {
        let hashed = references_field(&canonical, field);
        let exempted = exempt.iter().any(|e| e == field);
        if hashed && exempted {
            findings.push(missing(
                *line,
                format!("ArtifactHeader.{field} is both hashed in canonical() and ART_HASH_EXEMPT"),
            ));
        }
        if !hashed && !exempted {
            findings.push(missing(
                *line,
                format!(
                    "ArtifactHeader.{field} is neither hashed in canonical() nor named in \
                     ART_HASH_EXEMPT; decide its hash fate"
                ),
            ));
        }
    }
    for entry in &exempt {
        if !fields.iter().any(|(f, _)| f == entry) {
            findings.push(missing(
                exempt_line,
                format!("ART_HASH_EXEMPT names {entry:?}, which is not an ArtifactHeader field"),
            ));
        }
    }
    match fn_body(setup_src, "artifact_hash_disposition_witness") {
        Some((witness, wline)) => {
            for (field, _) in &fields {
                if !witness.contains(&format!("{field}:")) {
                    findings.push(missing(
                        wline,
                        format!(
                            "artifact_hash_disposition_witness does not destructure \
                             ArtifactHeader.{field}; the witness must stay exhaustive"
                        ),
                    ));
                }
            }
        }
        None => findings.push(missing(
            1,
            "no `fn artifact_hash_disposition_witness` found; the exhaustive destructuring \
             is what forces a hash decision on every new ArtifactHeader field"
                .to_string(),
        )),
    }
    findings
}

/// Recursively collect `.rs` files under `dir`, sorted by path so the
/// report order (and any future caching) is deterministic.
fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Registry location relative to `rust/src`.
pub const REGISTRY_PATH: &str = "rngtags.rs";
/// Plan module location relative to `rust/src` (rule 5).
pub const PLAN_PATH: &str = "dist/plan.rs";
/// Run-spec module location relative to `rust/src` (rule 5).
pub const SPEC_PATH: &str = "config/spec.rs";
/// Setup-artifact module location relative to `rust/src` (rule 5's
/// artifact leg).
pub const SETUP_PATH: &str = "setup/mod.rs";

/// Lint the whole tree rooted at the repo root (the directory holding
/// `Cargo.toml` and `rust/src`). Returns findings sorted by file/line;
/// an empty vector means the tree is clean.
pub fn lint_tree(repo_root: &Path) -> Result<Vec<Finding>> {
    let src_root = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let source =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        if rel == REGISTRY_PATH {
            findings.extend(lint_registry(&rel, &source));
        } else {
            findings.extend(lint_source(&rel, &source));
        }
    }
    let plan_file = src_root.join(PLAN_PATH);
    let spec_file = src_root.join(SPEC_PATH);
    let plan_src = std::fs::read_to_string(&plan_file)
        .with_context(|| format!("reading {}", plan_file.display()))?;
    let spec_src = std::fs::read_to_string(&spec_file)
        .with_context(|| format!("reading {}", spec_file.display()))?;
    findings.extend(check_plan_hash(PLAN_PATH, &plan_src, SPEC_PATH, &spec_src));
    let setup_file = src_root.join(SETUP_PATH);
    let setup_src = std::fs::read_to_string(&setup_file)
        .with_context(|| format!("reading {}", setup_file.display()))?;
    findings.extend(check_artifact_hash(SETUP_PATH, &setup_src));
    findings.sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture(name: &str) -> String {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("rust")
            .join("lint-fixtures")
            .join(name);
        std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {name}: {e}"))
    }

    #[test]
    fn sanitize_strips_strings_and_comments() {
        assert_eq!(sanitize("let x = 1; // .unwrap()"), "let x = 1; ");
        let s = sanitize(r#"let p = ".keys()"; m.keys();"#);
        assert!(!s.contains(".keys()\""));
        assert!(s.contains("m.keys()"));
        let s = sanitize(r#"let c = '"'; m.values();"#);
        assert!(s.contains("m.values()"));
    }

    #[test]
    fn test_region_needs_a_gated_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nfn helper() {}\nfn b() {}\n#[cfg(test)]\nmod tests {}\n";
        let lines: Vec<&str> = src.lines().collect();
        assert_eq!(test_region_start(&lines), 4, "only the mod-gating attribute opens it");
    }

    #[test]
    fn declared_idents_resolve_outer_container() {
        let u = declared_idents(
            "let mut counts: FastMap<u64, u32> = fast_map_with_capacity(4);",
            UNORDERED_TYPES,
            UNORDERED_CTORS,
        );
        assert_eq!(u, vec!["counts".to_string()]);
        // Nested unordered inside an ordered/sequential outer container
        // does not track the identifier.
        let u = declared_idents(
            "let maps: Vec<FastMap<u64, u32>> = Vec::new();",
            UNORDERED_TYPES,
            UNORDERED_CTORS,
        );
        assert!(u.is_empty(), "{u:?}");
        let o = declared_idents(
            "    pub overflow: BTreeMap<usize, SegmentMeta>,",
            ORDERED_TYPES,
            ORDERED_CTORS,
        );
        assert_eq!(o, vec!["overflow".to_string()]);
    }

    #[test]
    fn fixture_duplicate_fork_tag_trips() {
        let f = lint_registry("rngtags.rs", &fixture("dup_fork_tag.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::DuplicateForkTag && x.line == 7),
            "expected a duplicate-fork-tag finding on line 7, got {f:?}"
        );
    }

    #[test]
    fn fixture_raw_fork_trips() {
        let f = lint_source("quilt/bad.rs", &fixture("raw_fork.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::RawForkTag && x.line == 4),
            "expected a raw-fork-tag finding on line 4, got {f:?}"
        );
    }

    #[test]
    fn fixture_unsorted_iteration_trips() {
        let f = lint_source("quilt/bad.rs", &fixture("unsorted_iter.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::OrderLeak && x.line == 5),
            "expected an order-leak finding on line 5, got {f:?}"
        );
        // The annotated line stays clean.
        assert!(
            !f.iter().any(|x| x.line == 8),
            "annotated iteration must not be flagged: {f:?}"
        );
    }

    #[test]
    fn fixture_instant_in_kpgm_trips() {
        let f = lint_source("kpgm/bad.rs", &fixture("instant_in_kpgm.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::NondetSource && x.line == 4),
            "expected a nondet-source finding on line 4, got {f:?}"
        );
        // The same file outside the scope is fine.
        let f = lint_source("stats/fine.rs", &fixture("instant_in_kpgm.rs"));
        assert!(!f.iter().any(|x| x.rule == Rule::NondetSource), "{f:?}");
    }

    #[test]
    fn fixture_unannotated_unwrap_trips() {
        let f = lint_source("dist/bad.rs", &fixture("unannotated_unwrap.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::PanicPath && x.line == 5),
            "expected a panic-path finding on line 5, got {f:?}"
        );
        assert!(
            !f.iter().any(|x| x.line == 8),
            "annotated unwrap must not be flagged: {f:?}"
        );
        // Test code is exempt.
        assert!(!f.iter().any(|x| x.line > 10), "{f:?}");
    }

    #[test]
    fn fixture_unhashed_plan_field_trips() {
        let src = fixture("unhashed_plan_field.rs");
        let f = check_plan_hash("dist/plan.rs", &src, "config/spec.rs", &src);
        assert!(
            f.iter().any(|x| x.rule == Rule::HashDrift
                && x.message.contains("extra_knob")
                && x.line == 12),
            "expected a hash-drift finding for extra_knob on line 12, got {f:?}"
        );
        assert!(
            f.iter().any(|x| x.rule == Rule::HashDrift && x.message.contains("new_run_field")),
            "expected a hash-drift finding for new_run_field, got {f:?}"
        );
    }

    #[test]
    fn fixture_unhashed_artifact_field_trips() {
        let src = fixture("unhashed_artifact_field.rs");
        let f = check_artifact_hash("setup/mod.rs", &src);
        assert!(
            f.iter().any(|x| x.rule == Rule::HashDrift
                && x.message.contains("extra_knob")
                && x.message.contains("decide its hash fate")
                && x.line == 12),
            "expected a hash-drift finding for extra_knob on line 12, got {f:?}"
        );
        // The fixture's witness also misses that field.
        assert!(
            f.iter().any(|x| x.message.contains("witness")
                && x.message.contains("extra_knob")
                && x.line == 23),
            "expected a witness finding for extra_knob on line 23, got {f:?}"
        );
        // Fields with a declared fate stay clean.
        assert!(!f.iter().any(|x| x.message.contains(".seed")), "{f:?}");
        assert!(!f.iter().any(|x| x.message.contains("setup_ms")), "{f:?}");
    }

    #[test]
    fn stale_artifact_exempt_entry_trips() {
        let src = fixture("unhashed_artifact_field.rs")
            .replace("\"extra_stale\"", "\"not_a_field_anymore\"");
        let f = check_artifact_hash("setup/mod.rs", &src);
        assert!(
            f.iter().any(|x| x.message.contains("not_a_field_anymore")),
            "stale ART_HASH_EXEMPT entries must be flagged, got {f:?}"
        );
    }

    #[test]
    fn removing_an_artifact_exempt_entry_fails_the_tripwire() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let setup_src = std::fs::read_to_string(root.join("rust/src").join(SETUP_PATH))
            .expect("setup source");
        // The shipped header is clean…
        assert!(check_artifact_hash(SETUP_PATH, &setup_src).is_empty());
        // …and dropping either provenance knob from ART_HASH_EXEMPT (or
        // blinding the witness) trips it.
        for knob in ["\"setup_threads\"", "\"setup_ms\""] {
            let broken = setup_src.replacen(knob, "\"knob_gone\"", 1);
            let f = check_artifact_hash(SETUP_PATH, &broken);
            assert!(!f.is_empty(), "dropping {knob} from ART_HASH_EXEMPT must trip the lint");
        }
        let blinded = setup_src.replace("artifact_hash_disposition_witness", "renamed_away");
        let f = check_artifact_hash(SETUP_PATH, &blinded);
        assert!(
            f.iter().any(|x| x.message.contains("witness")),
            "removing the witness must trip the lint, got {f:?}"
        );
    }

    #[test]
    fn stale_hash_exempt_entry_trips() {
        let src = fixture("unhashed_plan_field.rs")
            .replace("\"extra_stale\"", "\"not_a_field_anymore\"");
        let f = check_plan_hash("dist/plan.rs", &src, "config/spec.rs", &src);
        assert!(
            f.iter().any(|x| x.message.contains("not_a_field_anymore")),
            "stale HASH_EXEMPT entries must be flagged, got {f:?}"
        );
    }

    #[test]
    fn fixture_fault_hook_in_kpgm_trips() {
        let f = lint_source("kpgm/bad.rs", &fixture("fault_in_kpgm.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::FaultHook && x.line == 5),
            "expected a fault-hook finding on line 5, got {f:?}"
        );
        assert!(
            !f.iter().any(|x| x.rule == Rule::FaultHook && x.line == 8),
            "annotated fault hook must not be flagged: {f:?}"
        );
        // The same source outside the output-determining scope is fine:
        // dist/fault.rs and its callers are exactly where the hooks live.
        let f = lint_source("dist/fault.rs", &fixture("fault_in_kpgm.rs"));
        assert!(!f.iter().any(|x| x.rule == Rule::FaultHook), "{f:?}");
    }

    #[test]
    fn fixture_trace_feeds_rng_trips() {
        // Outward direction: the sampler naming the trace machinery.
        let f = lint_source("kpgm/bad.rs", &fixture("trace_feeds_rng.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::TraceSink && x.line == 3),
            "expected a trace-sink finding on line 3, got {f:?}"
        );
        assert!(
            !f.iter().any(|x| x.rule == Rule::TraceSink && x.line == 8),
            "annotated trace use must not be flagged: {f:?}"
        );
        // Inward direction: trace/ touching the hashing machinery.
        let f = lint_source("trace/bad.rs", &fixture("trace_feeds_rng.rs"));
        assert!(
            f.iter().any(|x| x.rule == Rule::TraceSink && x.line == 11),
            "expected a trace-sink finding on line 11, got {f:?}"
        );
        // Outside both scopes the same source is fine: the coordinator
        // and the driver layers are exactly where trace handles live.
        let f = lint_source("coordinator/pool.rs", &fixture("trace_feeds_rng.rs"));
        assert!(!f.iter().any(|x| x.rule == Rule::TraceSink), "{f:?}");
    }

    #[test]
    fn trace_scope_covers_the_telemetry_layer() {
        for file in ["trace/mod.rs", "trace/console.rs", "trace/progress.rs", "trace/report.rs"] {
            assert!(in_trace_scope(file), "{file} must be trace-sink linted");
        }
        assert!(!in_trace_scope("coordinator/pool.rs"));
    }

    #[test]
    fn supervise_module_is_in_panic_scope() {
        // The supervisor kills child processes on unrecoverable errors; an
        // unannotated panic there would leak workers. The dist/ prefix rule
        // must keep covering it (and the doctor / fault modules).
        for file in ["dist/supervise.rs", "dist/doctor.rs", "dist/fault.rs"] {
            assert!(in_panic_scope(file), "{file} must be panic-path linted");
        }
    }

    #[test]
    fn shipped_tree_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(&root).expect("lint walks the tree");
        assert!(
            findings.is_empty(),
            "maglint found {} violation(s) in the shipped tree:\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn removing_a_hash_exempt_entry_fails_the_tripwire() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let plan_src =
            std::fs::read_to_string(root.join("rust/src").join(PLAN_PATH)).expect("plan source");
        let spec_src =
            std::fs::read_to_string(root.join("rust/src").join(SPEC_PATH)).expect("spec source");
        // The shipped pair is clean…
        assert!(check_plan_hash(PLAN_PATH, &plan_src, SPEC_PATH, &spec_src).is_empty());
        // …and dropping any single fate-list entry trips it. Edit only
        // from the HASH_EXEMPT declaration onward so the replacement hits
        // a fate list, never a TOML key string earlier in the file.
        let lists_at = plan_src.find("HASH_EXEMPT").expect("plan declares HASH_EXEMPT");
        let (head, lists) = plan_src.split_at(lists_at);
        for knob in [
            "\"workers\"",
            "\"setup_threads\"",
            "\"merge_threads\"",
            "\"worker_retries\"",
            "\"worker_backoff_ms\"",
        ] {
            let broken = format!("{head}{}", lists.replacen(knob, "\"knob_gone\"", 1));
            let f = check_plan_hash(PLAN_PATH, &broken, SPEC_PATH, &spec_src);
            assert!(!f.is_empty(), "dropping {knob} from the fate lists must trip the lint");
        }
    }
}
