//! Lightweight metrics: wall-clock timers, counters, and throughput
//! reporting used by the coordinator and the benchmark harnesses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A monotonically increasing counter, safe to bump from worker threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// New counter at zero.
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Scoped wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed milliseconds as f64.
    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// A registry of named durations and counters for end-of-run reports.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    durations: Mutex<BTreeMap<String, Duration>>,
    counters: Mutex<BTreeMap<String, u64>>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate a duration under `name`.
    pub fn record_duration(&self, name: &str, d: Duration) {
        let mut m = self.durations.lock().unwrap();
        *m.entry(name.to_string()).or_default() += d;
    }

    /// Accumulate a count under `name`.
    pub fn record_count(&self, name: &str, n: u64) {
        let mut m = self.counters.lock().unwrap();
        *m.entry(name.to_string()).or_default() += n;
    }

    /// Fetch a recorded duration.
    pub fn duration(&self, name: &str) -> Option<Duration> {
        self.durations.lock().unwrap().get(name).copied()
    }

    /// Fetch a recorded count.
    pub fn count(&self, name: &str) -> Option<u64> {
        self.counters.lock().unwrap().get(name).copied()
    }

    /// Render a sorted human-readable report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in self.durations.lock().unwrap().iter() {
            s.push_str(&format!("{k:<32} {:>12.3} ms\n", v.as_secs_f64() * 1e3));
        }
        for (k, v) in self.counters.lock().unwrap().iter() {
            s.push_str(&format!("{k:<32} {v:>12}\n"));
        }
        s
    }
}

/// Peak resident set size of this process in KiB, read from
/// `/proc/self/status` (`VmHWM`). Returns 0 on platforms without procfs
/// — the field is a hash-exempt observability estimate, never an input
/// to anything.
pub fn peak_rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
        }
    }
    0
}

/// Run `f` `reps` times and return the median wall-clock duration — the
/// primitive behind the bench harness (criterion is not in the vendored
/// crate set).
pub fn median_time<F: FnMut()>(reps: usize, mut f: F) -> Duration {
    assert!(reps > 0);
    let mut times: Vec<Duration> = (0..reps)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    times.sort_unstable();
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.add(3);
        c.add(4);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn registry_roundtrip() {
        let r = MetricsRegistry::new();
        r.record_count("edges", 10);
        r.record_count("edges", 5);
        r.record_duration("sample", Duration::from_millis(2));
        assert_eq!(r.count("edges"), Some(15));
        assert!(r.duration("sample").unwrap() >= Duration::from_millis(2));
        assert!(r.report().contains("edges"));
    }

    #[test]
    fn peak_rss_reads_or_degrades_to_zero() {
        // On Linux this is the real VmHWM high-water mark (a test
        // process certainly exceeds 100 KiB); elsewhere it degrades
        // to 0 rather than erroring.
        let kb = peak_rss_kb();
        assert!(kb == 0 || kb > 100);
    }

    #[test]
    fn median_time_runs() {
        let mut n = 0u64;
        let d = median_time(5, || n += 1);
        assert_eq!(n, 5);
        assert!(d < Duration::from_secs(1));
    }
}
