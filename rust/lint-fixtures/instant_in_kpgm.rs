// maglint fixture: wall-clock in an output-determining module.

pub fn elapsed_ms() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_millis()
}
