// maglint fixture: raw hex fork tag at a call site.

pub fn sample(rng: &Rng) -> u64 {
    rng.fork(0x1234).next_u64()
}
