// maglint fixture: fault-injection hook in an output-determining module.

pub fn sample_block(edges: &mut Vec<(u32, u32)>) {
    edges.push((0, 1));
    super::fault::inject_fault("crash-after-segments");
}

pub fn probe(f: &FaultPlan) -> bool { f.armed } // lint: fault-ok(fixture annotation)
