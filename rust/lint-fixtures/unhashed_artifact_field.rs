// maglint fixture: an ArtifactHeader field with no declared hash fate,
// and a witness that misses a field. Parsed by tests, not compiled.

pub struct ArtifactHeader {
    /// Hashed in canonical().
    pub seed: u64,
    /// Exempt provenance.
    pub setup_ms: f64,
    /// Exempt; the stale-entry test rewrites its list entry.
    pub extra_stale: usize,
    /// Neither hashed nor exempt: the tripwire target.
    pub extra_knob: usize,
}

impl ArtifactHeader {
    fn canonical(&self) -> String {
        format!("artifact|seed={}", self.seed)
    }
}

const ART_HASH_EXEMPT: &[&str] = &["setup_ms", "extra_stale"];

fn artifact_hash_disposition_witness(header: &ArtifactHeader) {
    let ArtifactHeader {
        seed: _,        // hashed
        setup_ms: _,    // ART_HASH_EXEMPT
        extra_stale: _, // ART_HASH_EXEMPT
    } = *header;
}
