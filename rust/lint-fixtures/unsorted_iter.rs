// maglint fixture: FastMap iteration order reaching the output.

pub fn emit(counts: &FastMap<u64, u32>) -> Vec<u64> {
    let mut out = Vec::new();
    for (&k, _) in counts.iter() {
        out.push(k);
    }
    let mut ordered: Vec<u64> = counts.keys().copied().collect(); // lint: order-ok(sorted on the next line)
    ordered.sort_unstable();
    out
}
