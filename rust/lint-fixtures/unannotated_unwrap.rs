// maglint fixture: panic path in an I/O module.

pub fn read_len(buf: &[u8]) -> usize {
    let head: [u8; 4] =
        buf[..4].try_into().unwrap();
    u32::from_le_bytes(head) as usize
}
pub fn first(buf: &[u8]) -> u8 { *buf.first().expect("nonempty") } // lint: panic-ok(fixture annotation)

#[cfg(test)]
mod tests {
    #[test]
    fn in_tests_unwrap_is_fine() {
        Some(1u32).unwrap();
    }
}
