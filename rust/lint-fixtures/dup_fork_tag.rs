// maglint fixture: two registry constants with the same tag value.

/// First stream.
pub const STREAM_A: u64 = 0xabc;

/// Second stream accidentally reuses the value.
pub const STREAM_B: u64 = 0xabc;
