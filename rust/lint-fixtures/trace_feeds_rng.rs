// maglint fixture: telemetry flowing against the write-only trace boundary.

pub fn leak_into_sampler(piece_seed: u64, t: &TraceHandle) -> u64 {
    let observed = t.lines().len() as u64;
    piece_seed ^ observed
}

pub fn status(t: &TraceHandle) { t.emit("note", &[]); } // lint: trace-ok(fixture annotation)

pub fn hash_trace_events(events: &[u8]) -> u64 {
    crate::hashutil::fnv1a(events)
}
