// maglint fixture: a ShardPlan field with no declared hash fate and a
// RunSpec field missing from the fate lists. Parsed by tests, not compiled.

pub struct ShardPlan {
    /// Hashed in canonical().
    pub seed: u64,
    /// Exempt per-host knob.
    pub workers: usize,
    /// Exempt; the stale-entry test rewrites its list entry.
    pub extra_stale: usize,
    /// Neither hashed nor exempt: the tripwire target.
    pub extra_knob: usize,
}

impl ShardPlan {
    fn canonical(&self) -> String {
        format!("plan|seed={}", self.seed)
    }
}

const HASH_EXEMPT: &[&str] = &["workers", "extra_stale"];

pub struct RunSpec {
    pub seed: u64,
    pub workers: usize,
    pub new_run_field: usize,
}

const RUNSPEC_HASHED: &[&str] = &["seed"];
const RUNSPEC_EXEMPT: &[&str] = &["workers"];
