//! End-to-end driver: proves all layers compose on a real workload.
//!
//! Pipeline exercised:
//!   1. AOT artifacts (Pallas kernel → HLO) are loaded by the PJRT
//!      runtime and numerically cross-checked against the pure-Rust model
//!      (L1/L2 ↔ L3 contract),
//!   2. the coordinator samples MAGM graphs across the worker pool for a
//!      sweep of n — the paper's headline workload — with the naive
//!      baseline run at the sizes it can afford,
//!   3. graph statistics and the paper's headline metric (per-edge
//!      sampling cost, constant in n) are reported; degree expectations
//!      from the XLA kernel are validated against the sampled graphs.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use magquilt::coordinator::Coordinator;
use magquilt::kpgm::Initiator;
use magquilt::magm::{naive_sample, AttributeAssignment, MagmParams};
use magquilt::rng::Rng;
use magquilt::runtime::{expected_out_degrees, MagmKernels, XlaRuntime};
use magquilt::stats::{mean, summarize};

fn main() -> anyhow::Result<()> {
    println!("== stage 1: runtime artifacts =====================================");
    let runtime = XlaRuntime::load_default()?;
    println!("PJRT platform: {}", runtime.platform());
    let check_params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 256, 12);
    let mut rng = Rng::new(5);
    let check_attrs = AttributeAssignment::sample(&check_params, &mut rng);
    let kernels = MagmKernels::new(&runtime, check_params.thetas());
    let src: Vec<u32> = (0..128).collect();
    let dst: Vec<u32> = (128..256).collect();
    let q = kernels.edge_prob_block(&check_attrs, &src, &dst)?;
    let mut max_err = 0.0f64;
    for (r, &i) in src.iter().enumerate() {
        for (c, &j) in dst.iter().enumerate() {
            let want = magquilt::magm::edge_probability(&check_params, &check_attrs, i, j);
            max_err = max_err.max((q[r * dst.len() + c] as f64 - want).abs());
        }
    }
    println!("XLA edge_prob_block vs pure-Rust: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-5, "runtime numerics check failed");

    println!("\n== stage 2: coordinated sampling sweep ============================");
    println!("{:>7} {:>10} {:>4} {:>12} {:>12} {:>14} {:>12}",
             "n", "edges", "B", "quilt_ms", "naive_ms", "us_per_edge", "edges/s");
    let coordinator = Coordinator::new();
    let seed = 42;
    let naive_cap = 1 << 11;
    let mut per_edge_us = Vec::new();
    let mut last_graph = None;
    for d in [10u32, 12, 14, 16] {
        let n = 1usize << d;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let report = coordinator.sample_quilt(&params, seed);
        let naive_ms = if n <= naive_cap {
            let mut rng = Rng::new(seed);
            let attrs = AttributeAssignment::sample(&params, &mut rng);
            let t = Instant::now();
            let _ = naive_sample(&params, &attrs, &mut rng);
            format!("{:.1}", t.elapsed().as_secs_f64() * 1e3)
        } else {
            "-".into()
        };
        let us = report.wall_ms * 1e3 / report.graph.num_edges().max(1) as f64;
        per_edge_us.push(us);
        println!(
            "{:>7} {:>10} {:>4} {:>12.1} {:>12} {:>14.3} {:>12.2e}",
            n,
            report.graph.num_edges(),
            report.partition_size,
            report.wall_ms,
            naive_ms,
            us,
            report.edges_per_sec
        );
        if d == 14 {
            last_graph = Some((params, report.graph));
        }
    }
    println!(
        "headline: per-edge cost across the sweep: {:.3} ± {:.3} us (paper Fig. 11: ~constant)",
        mean(&per_edge_us),
        magquilt::stats::std_dev(&per_edge_us)
    );

    println!("\n== stage 3: statistics + XLA degree validation ====================");
    let (params, graph) = last_graph.expect("sweep includes d = 14");
    let summary = summarize(&graph, 2000, 7);
    print!("{}", summary.report());

    // Validate expected degrees from the XLA kernel against the sample:
    // total expected out-degree must match |E| closely.
    let mut rng = Rng::new(seed);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let t = Instant::now();
    let deg = expected_out_degrees(&runtime, &params, &attrs)?;
    let expected_total: f64 = deg.iter().sum();
    println!(
        "XLA expected |E| for this attribute draw: {:.0} (sampled: {}; {:.1} ms to compute)",
        expected_total,
        graph.num_edges(),
        t.elapsed().as_secs_f64() * 1e3
    );
    let rel = (expected_total - graph.num_edges() as f64).abs() / expected_total;
    println!("relative gap: {:.3} (sampling noise + ball-drop approximation)", rel);
    assert!(rel < 0.05, "expected-degree validation failed");
    println!("\nEND-TO-END OK");
    Ok(())
}
