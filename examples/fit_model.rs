//! Model fitting: recover the generator's Θ from one observed graph.
//!
//! The paper's third motivating use case is growth prediction: "fit the
//! model on the current graph and generate a larger graph with the
//! estimated parameters". This example runs that loop end to end:
//!
//! 1. generate an "observed" network from Θ1 with the quilting sampler,
//! 2. fit μ̂ (closed form) and Θ̂ (sufficient-statistics MLE, coordinate
//!    ascent — see `magquilt::fit`),
//! 3. generate a 4× larger graph from the fitted parameters and compare
//!    its statistics against a 4× graph from the true parameters.
//!
//! ```bash
//! cargo run --release --example fit_model
//! ```

use magquilt::fit::{fit_mu, fit_theta, FitOptions};
use magquilt::kpgm::Initiator;
use magquilt::magm::{AttributeAssignment, MagmParams};
use magquilt::quilt::QuiltSampler;
use magquilt::rng::Rng;
use magquilt::stats::summarize;

fn main() {
    let d = 12;
    let n = 1usize << d;
    let truth = Initiator::THETA1;

    // --- 1. observe a network -----------------------------------------
    let params = MagmParams::homogeneous(truth, 0.5, n, d);
    let mut rng = Rng::new(2021);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let observed = QuiltSampler::new(params).seed(7).sample_with_attrs(&attrs);
    println!("observed: n = {n}, |E| = {}", observed.num_edges());

    // --- 2. fit --------------------------------------------------------
    let mu_hat = fit_mu(&attrs);
    println!(
        "mu-hat: mean {:.4} (truth 0.5), range [{:.4}, {:.4}]",
        mu_hat.iter().sum::<f64>() / mu_hat.len() as f64,
        mu_hat.iter().cloned().fold(f64::INFINITY, f64::min),
        mu_hat.iter().cloned().fold(0.0, f64::max),
    );
    let start = std::time::Instant::now();
    let fit = fit_theta(&observed, &attrs, Initiator::new([0.5; 4]), FitOptions::default());
    println!(
        "theta-hat after {} sweeps ({:.1} ms): {:?}  (truth {:?})",
        fit.sweeps,
        start.elapsed().as_secs_f64() * 1e3,
        fit.theta.entries().map(|e| (e * 1000.0).round() / 1000.0),
        truth.entries(),
    );
    println!("log-likelihood trajectory: {:?}",
             fit.trajectory.iter().map(|l| l.round()).collect::<Vec<_>>());

    // --- 3. growth prediction: 4x graph from fitted vs true params ----
    let big_d = d + 2;
    let big_n = n << 2;
    for (name, theta) in [("fitted", fit.theta), ("true  ", truth)] {
        let p = MagmParams::homogeneous(theta, 0.5, big_n, big_d);
        let g = QuiltSampler::new(p).seed(99).sample();
        let s = summarize(&g, 1000, 1);
        println!(
            "{name} theta -> 4x graph: |E| = {:>8}, scc = {:.3}, mean deg = {:.2}, alpha = {:?}",
            s.num_edges,
            s.scc_fraction,
            s.mean_degree,
            s.powerlaw_alpha.map(|a| (a * 100.0).round() / 100.0),
        );
    }
    println!("(fitted and true 4x graphs should have closely matching statistics)");
}
