//! Quickstart: sample a MAGM graph with the quilting sampler and print its
//! statistics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use magquilt::coordinator::Coordinator;
use magquilt::kpgm::Initiator;
use magquilt::magm::MagmParams;
use magquilt::stats::summarize;

fn main() {
    // Kim & Leskovec's theta, balanced attributes, n = 2^14 nodes.
    let d = 14;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << d, d);

    println!("expected edges (analytic): {:.0}", params.expected_edges());

    // Sample across the worker pool (Algorithm 2 pieces in parallel).
    let report = Coordinator::new().sample_quilt(&params, 42);
    println!(
        "sampled {} edges | B = {} | {} jobs on {} workers | {:.1} ms ({:.2e} edges/s)",
        report.graph.num_edges(),
        report.partition_size,
        report.num_jobs,
        report.workers,
        report.wall_ms,
        report.edges_per_sec,
    );

    // Graph statistics (paper §6.1's properties).
    let summary = summarize(&report.graph, 2000, 42);
    print!("{}", summary.report());
}
