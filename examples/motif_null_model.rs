//! Motif over-representation test (the paper's second motivating use
//! case, after Shen-Orr et al. 2002): approximate the null distribution of
//! a motif count by sampling many graphs from the fitted model, then
//! report an empirical p-value for the observed count.
//!
//! The motif is the feed-forward loop (i→j, j→k, i→k), counted on a
//! degree-bounded subsample for tractability.
//!
//! ```bash
//! cargo run --release --example motif_null_model
//! ```

use magquilt::graph::{Csr, EdgeList};
use magquilt::kpgm::Initiator;
use magquilt::magm::MagmParams;
use magquilt::quilt::QuiltSampler;
use magquilt::stats::{mean, std_dev};

/// Count feed-forward loops i→j→k with i→k.
fn count_ffl(g: &EdgeList) -> u64 {
    let csr = Csr::from_edge_list(g);
    let mut count = 0u64;
    for i in 0..csr.num_nodes() as u32 {
        for &j in csr.neighbors(i) {
            if j == i {
                continue;
            }
            for &k in csr.neighbors(j) {
                if k != i && k != j && csr.has_edge(i, k) {
                    count += 1;
                }
            }
        }
    }
    count
}

fn main() {
    let d = 10;
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);

    // "Observed" graph: a sample with extra triangles injected, playing
    // the role of a real network whose motif count we test.
    let mut observed = QuiltSampler::new(params.clone()).seed(2024).sample();
    let base_edges = observed.num_edges();
    // Inject feed-forward closures on existing 2-paths (cheaply: close the
    // first few hundred open wedges).
    {
        let csr = Csr::from_edge_list(&observed);
        let mut injected = 0;
        'outer: for i in 0..csr.num_nodes() as u32 {
            for &j in csr.neighbors(i) {
                for &k in csr.neighbors(j) {
                    if k != i && !csr.has_edge(i, k) {
                        observed.push(i, k);
                        injected += 1;
                        if injected >= 300 {
                            break 'outer;
                        }
                    }
                }
            }
        }
        observed.dedup();
    }
    let observed_count = count_ffl(&observed);
    println!(
        "observed graph: {} edges ({} baseline + injected), {} feed-forward loops",
        observed.num_edges(),
        base_edges,
        observed_count
    );

    // Null distribution from the model.
    let trials = 60;
    let mut counts = Vec::with_capacity(trials);
    for t in 0..trials as u64 {
        let g = QuiltSampler::new(params.clone()).seed(t).sample();
        counts.push(count_ffl(&g) as f64);
    }
    let m = mean(&counts);
    let s = std_dev(&counts);
    let exceed = counts.iter().filter(|&&c| c >= observed_count as f64).count();
    let p_value = (exceed as f64 + 1.0) / (trials as f64 + 1.0);
    println!("null FFL count over {trials} samples: mean {m:.1} ± {s:.1}");
    println!(
        "empirical p-value for observed {} FFLs: {:.4} (z = {:+.2})",
        observed_count,
        p_value,
        (observed_count as f64 - m) / s.max(1e-9)
    );
    if p_value < 0.05 {
        println!("=> the motif is over-represented at the 5% level (as constructed)");
    } else {
        println!("=> not significant at the 5% level");
    }
}
