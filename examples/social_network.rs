//! Goodness-of-fit workload (the paper's first motivating use case):
//! generate graphs from a fitted model and compare graph statistics of the
//! samples against a "reference" network, plus a model log-likelihood
//! computed through the AOT XLA kernel.
//!
//! The reference network here is itself a MAGM draw (playing the role of
//! the observed social network); we then score two candidate parameter
//! settings by (a) summary-statistic distance over repeated samples and
//! (b) Bernoulli log-likelihood of the observed adjacency under Q — the
//! Hunter et al. (2008) style check cited in the paper's introduction.
//!
//! ```bash
//! make artifacts && cargo run --release --example social_network
//! ```

use magquilt::graph::Csr;
use magquilt::kpgm::Initiator;
use magquilt::magm::{AttributeAssignment, MagmParams};
use magquilt::quilt::QuiltSampler;
use magquilt::rng::Rng;
use magquilt::runtime::{MagmKernels, XlaRuntime};
use magquilt::stats::{mean, summarize};

fn main() -> anyhow::Result<()> {
    let d = 12;
    let n = 1usize << d;

    // --- The "observed" network: a MAGM draw with theta1. -------------
    let truth = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    let mut rng = Rng::new(1234);
    let observed_attrs = AttributeAssignment::sample(&truth, &mut rng);
    let observed = QuiltSampler::new(truth.clone()).seed(99).sample_with_attrs(&observed_attrs);
    let obs_summary = summarize(&observed, 2000, 7);
    println!("observed network: {} nodes, {} edges", n, observed.num_edges());
    print!("{}", obs_summary.report());

    // --- Candidate models to score. ------------------------------------
    let candidates = [
        ("theta1 (true)", MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d)),
        ("theta2 (wrong)", MagmParams::homogeneous(Initiator::THETA2, 0.5, n, d)),
    ];

    // (a) summary-statistic goodness of fit over repeated samples.
    println!("\n== summary-statistic fit (10 samples per model) ==");
    for (name, params) in &candidates {
        let mut edge_counts = Vec::new();
        let mut sccs = Vec::new();
        for t in 0..10u64 {
            let g = QuiltSampler::new(params.clone()).seed(t).sample();
            edge_counts.push(g.num_edges() as f64);
            let csr = Csr::from_edge_list(&g);
            sccs.push(magquilt::graph::largest_scc_size(&csr) as f64 / n as f64);
        }
        let e_err = (mean(&edge_counts) - observed.num_edges() as f64).abs()
            / observed.num_edges() as f64;
        let s_err = (mean(&sccs) - obs_summary.scc_fraction).abs();
        println!(
            "{name:>15}: |E| rel err {:.3}, SCC-fraction err {:.4}",
            e_err, s_err
        );
    }

    // (b) log-likelihood of the observed adjacency under each model's Q,
    //     evaluated block-wise by the AOT XLA kernel.
    println!("\n== Bernoulli log-likelihood via XLA loglik_block kernel ==");
    let runtime = XlaRuntime::load_default()?;
    let block = runtime.manifest().bm;
    for (name, params) in &candidates {
        let kernels = MagmKernels::new(&runtime, params.thetas());
        let csr = Csr::from_edge_list(&observed);
        let all: Vec<u32> = (0..n as u32).collect();
        let mut ll = 0.0f64;
        for src in all.chunks(block) {
            for dst in all.chunks(block) {
                let mut adj = vec![0f32; src.len() * dst.len()];
                for (r, &i) in src.iter().enumerate() {
                    for &j in csr.neighbors(i) {
                        if (dst[0]..dst[0] + dst.len() as u32).contains(&j) {
                            adj[r * dst.len() + (j - dst[0]) as usize] = 1.0;
                        }
                    }
                }
                ll += kernels.loglik_block(&observed_attrs, src, dst, &adj)?;
            }
        }
        println!("{name:>15}: log-likelihood {ll:.1}");
    }
    println!("\n(the true model should score highest on both criteria)");
    Ok(())
}
