//! Ablation bench for the design choices DESIGN.md calls out:
//!
//! 1. dense config→node index vs hash-map filter in the piece hot loop,
//! 2. the calibrated wall-time B' model vs the paper's abstract T(B'),
//! 3. hybrid (§5) vs plain Algorithm 2 at skewed μ.

use std::time::Instant;

use magquilt::kpgm::Initiator;
use magquilt::magm::{AttributeAssignment, MagmParams};
use magquilt::quilt::{choose_b_prime, cost_model_paper, HybridSampler, Partition, QuiltSampler};
use magquilt::rng::Rng;

fn main() {
    let fast = std::env::var("MAGQUILT_BENCH_FAST").is_ok();
    let d: u32 = if fast { 11 } else { 14 };
    let n = 1usize << d;

    // --- 1. dense index vs hash map (build-only comparison; the sampler
    //        always uses dense when affordable, so measure the lookup
    //        machinery via partition ops). -------------------------------
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    let mut rng = Rng::new(9);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let mut partition = Partition::build(attrs.configs());
    let reps: u64 = if fast { 2_000_000 } else { 20_000_000 };

    let mut acc = 0u64;
    let start = Instant::now();
    for i in 0..reps {
        let cfg = i % (1 << d);
        if let Some(v) = partition.map(0).get(&cfg) {
            acc ^= *v as u64;
        }
    }
    let hash_ns = start.elapsed().as_nanos() as f64 / reps as f64;

    partition.build_dense_index(1 << d);
    let start = Instant::now();
    for i in 0..reps {
        let cfg = i % (1 << d);
        if let Some(v) = partition.lookup(0, cfg) {
            acc ^= v as u64;
        }
    }
    let dense_ns = start.elapsed().as_nanos() as f64 / reps as f64;
    println!("# ablation 1: piece filter lookup (per ball drop)");
    println!("hash-map: {hash_ns:.1} ns | dense index: {dense_ns:.1} ns | {:.1}x (sink {acc})",
             hash_ns / dense_ns);

    // --- 2. B' selection: calibrated wall model vs paper T(B'). ---------
    println!("\n# ablation 2: B' choice, hybrid wall time (mu sweep, n = 2^{d})");
    println!("{:>5} {:>10} {:>14} {:>14} {:>12}", "mu", "B'_wall", "wall_model_ms", "paper_model_ms", "ratio");
    for &mu in &[0.5, 0.7, 0.9] {
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
        let mut rng = Rng::new(11);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let counts = attrs.config_counts();
        let (bp_wall, _) =
            choose_b_prime(&counts, n, d as usize, params.thetas().expected_edges());
        // paper model B' (reconstructed the way §5 writes it)
        let mut mults: Vec<u32> = counts.iter().map(|&(_, m)| m).collect();
        mults.sort_unstable();
        let mut cands: Vec<u32> = mults.clone();
        cands.dedup();
        cands.push(0);
        let mut bp_paper = (u32::MAX, f64::INFINITY);
        for &bp in &cands {
            let split = mults.partition_point(|&m| m <= bp);
            let w: u64 = mults[..split].iter().map(|&m| m as u64).sum();
            let r = (mults.len() - split) as f64;
            let t = cost_model_paper(
                bp as f64,
                w as f64,
                r,
                (n as f64).log2(),
                d as f64,
                params.expected_edges(),
            );
            if t < bp_paper.1 {
                bp_paper = (bp, t);
            }
        }
        let time_with = |bp: u32| -> f64 {
            let mut best = f64::INFINITY;
            for t in 0..2 {
                let start = Instant::now();
                let _ = HybridSampler::new(params.clone())
                    .seed(t)
                    .b_prime(bp)
                    .sample_with_attrs(&attrs);
                best = best.min(start.elapsed().as_secs_f64() * 1e3);
            }
            best
        };
        let wall_ms = time_with(bp_wall);
        let paper_ms = time_with(bp_paper.0);
        println!(
            "{mu:>5.1} {bp_wall:>10} {wall_ms:>14.1} {paper_ms:>14.1} {:>12.2}x",
            paper_ms / wall_ms
        );
    }

    // --- 3. hybrid vs plain quilt at skewed mu. -------------------------
    // Fixed small n: plain Algorithm 2 at mu = 0.9 has B ~ n mu^d, so the
    // B² piece count explodes with n — that explosion IS the result.
    let d3: u32 = 10;
    let n3 = 1usize << d3;
    println!("\n# ablation 3: §5 hybrid vs plain Algorithm 2 (n = 2^{d3})");
    println!("{:>5} {:>12} {:>12} {:>8}", "mu", "quilt_ms", "hybrid_ms", "win");
    for &mu in &[0.7, 0.8, 0.9] {
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, n3, d3);
        let mut best_q = f64::INFINITY;
        let mut best_h = f64::INFINITY;
        for t in 0..2u64 {
            let start = Instant::now();
            let _ = QuiltSampler::new(params.clone()).seed(t).sample();
            best_q = best_q.min(start.elapsed().as_secs_f64() * 1e3);
            let start = Instant::now();
            let _ = HybridSampler::new(params.clone()).seed(t).sample();
            best_h = best_h.min(start.elapsed().as_secs_f64() * 1e3);
        }
        println!("{mu:>5.1} {best_q:>12.1} {best_h:>12.1} {:>8.1}x", best_q / best_h);
    }
}
