//! μ-sweep bench — the series behind paper Figures 12 and 13: relative
//! running time ρ(μ) of the hybrid sampler, and the ablation plain-quilt
//! vs hybrid at high μ (the §5 speedup's payoff).

use std::time::Instant;

use magquilt::kpgm::Initiator;
use magquilt::magm::MagmParams;
use magquilt::quilt::{HybridSampler, QuiltSampler};

fn time_one<F: FnMut() -> usize>(trials: u32, mut f: F) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut edges = 0;
    for _ in 0..trials {
        let start = Instant::now();
        edges = f();
        best = best.min(start.elapsed().as_secs_f64() * 1e3);
    }
    (best, edges)
}

fn main() {
    let fast = std::env::var("MAGQUILT_BENCH_FAST").is_ok();
    let (d, trials) = if fast { (10u32, 2u32) } else { (14, 3) };
    let n = 1usize << d;
    println!("# bench: mu sweep at n = 2^{d} (paper Fig. 12/13) + §5 ablation");
    println!(
        "{:>5} {:>12} {:>12} {:>8} {:>12} {:>10}",
        "mu", "hybrid_ms", "quilt_ms", "rho", "edges", "hybrid_win"
    );
    let mut t_half = f64::NAN;
    for &mu in &[0.1, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9] {
        let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
        let p1 = params.clone();
        let mut seed = 0u64;
        let (hybrid_ms, edges) = time_one(trials, move || {
            seed += 1;
            HybridSampler::new(p1.clone()).seed(seed).sample().num_edges()
        });
        // Plain Algorithm 2 for the ablation. Away from mu = 0.5 this is
        // the expensive path (B ~ n·max(mu, 1-mu)^d, so B² pieces explode
        // symmetrically toward both mu → 0 and mu → 1) — cap it.
        let quilt_ms = if (0.4..=0.6).contains(&mu) || fast {
            let p2 = params.clone();
            let mut seed = 100u64;
            let (ms, _) = time_one(trials.min(2), move || {
                seed += 1;
                QuiltSampler::new(p2.clone()).seed(seed).sample().num_edges()
            });
            Some(ms)
        } else {
            None
        };
        if (mu - 0.5).abs() < 1e-9 {
            t_half = hybrid_ms;
        }
        println!(
            "{:>5.1} {:>12.2} {:>12} {:>8} {:>12} {:>10}",
            mu,
            hybrid_ms,
            quilt_ms.map_or("-".into(), |v| format!("{v:.2}")),
            if t_half.is_nan() { "-".into() } else { format!("{:.2}", hybrid_ms / t_half) },
            edges,
            quilt_ms.map_or("-".into(), |v| format!("{:.2}x", v / hybrid_ms)),
        );
    }
    println!("(rho is relative to mu=0.5; hybrid_win is quilt_ms / hybrid_ms)");
}
