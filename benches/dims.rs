//! Dimension-sweep bench — the series behind paper Figure 14: runtime vs
//! the number of attributes d at fixed n (exponential blowup past
//! d = log2 n, §4.2).

use std::time::Instant;

use magquilt::kpgm::Initiator;
use magquilt::magm::MagmParams;
use magquilt::quilt::QuiltSampler;

fn main() {
    let fast = std::env::var("MAGQUILT_BENCH_FAST").is_ok();
    let log2n: u32 = if fast { 10 } else { 14 };
    let n = 1usize << log2n;
    println!("# bench: d sweep at n = 2^{log2n} (paper Fig. 14)");
    println!("{:>4} {:>12} {:>10}", "d", "quilt_ms", "note");
    for d in (log2n - 4)..=(log2n + 3) {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let trials = if d > log2n { 1 } else { 3 };
        let mut best = f64::INFINITY;
        for t in 0..trials {
            let start = Instant::now();
            let _ = QuiltSampler::new(params.clone()).seed(t).sample();
            best = best.min(start.elapsed().as_secs_f64() * 1e3);
        }
        let note = if d == log2n { "<- d = log2 n" } else { "" };
        println!("{d:>4} {best:>12.2} {note:>10}");
    }
}
