//! Hot-path microbenchmarks for Algorithm 1: quadrisection descent cost,
//! edge-count draw, full KPGM samples. This is the inner loop that every
//! quilt piece pays `X` times — the primary L3 optimization target.

use std::time::Instant;

use magquilt::kpgm::{BallDropSampler, Initiator, ThetaSeq};
use magquilt::rng::Rng;

fn fast() -> bool {
    std::env::var("MAGQUILT_BENCH_FAST").is_ok()
}

fn main() {
    let reps: u64 = if fast() { 1_000_000 } else { 10_000_000 };
    println!("# bench: kpgm core (Algorithm 1 inner loop)");

    // Raw RNG throughput for context.
    let mut rng = Rng::new(1);
    let start = Instant::now();
    let mut acc = 0u64;
    for _ in 0..reps {
        acc ^= rng.next_u64();
    }
    let ns = start.elapsed().as_nanos() as f64 / reps as f64;
    println!("rng.next_u64: {ns:.2} ns/call (sink {acc})");

    // categorical4 (the descent's per-level op).
    let w = Initiator::THETA1.weights();
    let start = Instant::now();
    let mut acc2 = 0usize;
    for _ in 0..reps {
        acc2 += rng.categorical4(&w);
    }
    let ns = start.elapsed().as_nanos() as f64 / reps as f64;
    println!("rng.categorical4: {ns:.2} ns/call (sink {acc2})");

    // Full descent at several depths.
    for d in [10u32, 16, 20, 24] {
        let sampler = BallDropSampler::new(ThetaSeq::homogeneous(Initiator::THETA1, d));
        let drops = reps / d as u64;
        let start = Instant::now();
        let mut acc3 = 0u64;
        for _ in 0..drops {
            let (s, t) = sampler.drop_one(&mut rng);
            acc3 ^= (s as u64) << 32 | t as u64;
        }
        let ns = start.elapsed().as_nanos() as f64 / drops as f64;
        println!(
            "drop_one d={d}: {ns:.1} ns/drop = {:.2} ns/level (sink {acc3})",
            ns / d as f64
        );
    }

    // End-to-end KPGM sample (includes dedup set).
    for d in [12u32, 16, 18] {
        let sampler = BallDropSampler::new(ThetaSeq::homogeneous(Initiator::THETA1, d));
        let trials = if fast() { 2 } else { 5 };
        let mut best = f64::INFINITY;
        let mut edges = 0;
        for t in 0..trials {
            let mut r = Rng::new(t);
            let start = Instant::now();
            let g = sampler.sample(&mut r);
            best = best.min(start.elapsed().as_secs_f64() * 1e3);
            edges = g.num_edges();
        }
        println!(
            "kpgm sample d={d}: {best:.2} ms for {edges} edges = {:.0} ns/edge",
            best * 1e6 / edges.max(1) as f64
        );
    }
}
