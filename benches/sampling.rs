//! End-to-end sampling bench — regenerates the series behind paper
//! Figures 10 and 11 (quilting vs naive runtime, and per-edge cost), the
//! conditioned-vs-rejection piece sweep over partition size B, the
//! shard-count sweep of the coordinator's streaming merge (per-shard
//! merge stats included), the setup-pipeline sweep over setup-thread
//! counts (per-phase attrs/partition/trie/trie-merge/DAG timings), the
//! distributed-runtime sweep over worker counts (partitioned sampling +
//! segment merge), and the segment-merge sweep over merge-thread counts
//! (one fixed segment directory, T ∈ {1, 2, 4, 8}), and the setup-reuse
//! sweep (fresh setup + sample vs hydrating the same run from a saved
//! `MAGQART1` setup artifact — docs/setup-artifact.md), and the
//! trace-overhead sweep (the identical run with telemetry off vs on —
//! docs/observability.md). Summaries are emitted to `BENCH_quilt.json`
//! for the perf trajectory; every section renders through the shared
//! report serializer (`magquilt::trace::report`), so the bench and
//! `report.json` agree on field names by construction.
//!
//! `MAGQUILT_BENCH_FAST=1` shrinks the sweeps for smoke runs.

use std::time::Instant;

use magquilt::config::{ModelSpec, RunSpec, SamplerKind};
use magquilt::coordinator::Coordinator;
use magquilt::dist::{self, ShardPlan};
use magquilt::kpgm::Initiator;
use magquilt::magm::{naive_sample, AttributeAssignment, MagmParams};
use magquilt::quilt::{HybridSampler, Partition, PieceMode, QuiltSampler};
use magquilt::rng::Rng;
use magquilt::setup::SetupArtifact;
use magquilt::trace::report::{shard_stats_obj, spill_obj, JsonObj};
use magquilt::trace::TraceHandle;

fn fast() -> bool {
    std::env::var("MAGQUILT_BENCH_FAST").is_ok()
}

/// One `BENCH_quilt.json` section: meta fields plus result rows, all
/// rendered through the shared report serializer.
fn section(name: &str, meta: JsonObj, rows: Vec<String>) -> String {
    format!("  \"{name}\": {}", meta.arr("results", rows).render())
}

/// Attribute assignment with exactly `b`-fold multiplicity for each of
/// `c_distinct` random distinct configs: partition size is exactly B = b.
fn attrs_with_b(b: usize, c_distinct: usize, d: usize, seed: u64) -> AttributeAssignment {
    let mut rng = Rng::new(seed);
    let mut set = std::collections::HashSet::new();
    while set.len() < c_distinct {
        set.insert(rng.below(1u64 << d));
    }
    let mut cfgs: Vec<u64> = set.into_iter().collect();
    cfgs.sort_unstable();
    let mut configs = Vec::with_capacity(b * c_distinct);
    for &c in &cfgs {
        configs.extend(std::iter::repeat(c).take(b));
    }
    AttributeAssignment::from_configs(configs, d as u32)
}

/// Conditioned-vs-rejection piece benchmark sweeping partition size B.
/// Returns the JSON rows for `BENCH_quilt.json`.
fn piece_mode_sweep() -> String {
    let d = 12usize;
    let (bs, c_distinct, trials): (&[usize], usize, u64) =
        if fast() { (&[4, 16], 64, 2) } else { (&[4, 16, 64], 192, 3) };
    println!("\n# bench: conditioned vs rejection pieces (theta1, d={d}, B sweep)");
    println!(
        "{:>4} {:>8} {:>8} {:>12} {:>12} {:>10}",
        "B", "n", "edges", "cond_ms", "rej_ms", "speedup"
    );
    let mut rows = Vec::new();
    for &b in bs {
        let n = b * c_distinct;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d as u32);
        let attrs = attrs_with_b(b, c_distinct, d, b as u64);
        let time_mode = |mode: PieceMode| -> (f64, usize) {
            let mut ms = Vec::new();
            let mut edges = 0usize;
            for t in 0..trials {
                let start = Instant::now();
                let g = QuiltSampler::new(params.clone())
                    .piece_mode(mode)
                    .seed(t)
                    .sample_with_attrs(&attrs);
                ms.push(start.elapsed().as_secs_f64() * 1e3);
                edges = g.num_edges();
            }
            (median(&mut ms), edges)
        };
        let (cond, cond_edges) = time_mode(PieceMode::Conditioned);
        let (rej, rej_edges) = time_mode(PieceMode::Rejection);
        let speedup = rej / cond.max(1e-9);
        println!(
            "{:>4} {:>8} {:>8} {:>12.2} {:>12.2} {:>9.1}x",
            b, n, cond_edges, cond, rej, speedup
        );
        rows.push(
            JsonObj::new()
                .uint("b", b as u64)
                .uint("n", n as u64)
                .uint("edges_conditioned", cond_edges as u64)
                .uint("edges_rejection", rej_edges as u64)
                .float("conditioned_ms", cond)
                .float("rejection_ms", rej)
                .float("speedup", speedup)
                .render(),
        );
    }
    section(
        "piece_modes",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("trials", trials),
        rows,
    )
}

/// Shard-count sweep of the coordinator's streaming merge: same model,
/// same seed, S ∈ {1, 2, 4, 8} — the edge set is identical by
/// construction, so the sweep isolates merge throughput and per-shard
/// residency. Returns the JSON rows for `BENCH_quilt.json`.
fn shard_sweep() -> String {
    let (d, shard_counts, trials): (u32, &[usize], u64) =
        if fast() { (12, &[1, 4], 2) } else { (15, &[1, 2, 4, 8], 3) };
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    println!("\n# bench: coordinator shard sweep (theta1, d={d}, n=2^{d})");
    println!(
        "{:>4} {:>8} {:>10} {:>14} {:>14} {:>12}",
        "S", "edges", "wall_ms", "edges/s", "peak_resident", "dups_dropped"
    );
    let mut rows = Vec::new();
    for &s in shard_counts {
        let coord = Coordinator::new().shards(s);
        let mut ms = Vec::new();
        let mut last = None;
        for t in 0..trials {
            let start = Instant::now();
            let rep = coord.sample_quilt(&params, t);
            ms.push(start.elapsed().as_secs_f64() * 1e3);
            last = Some(rep);
        }
        let wall = median(&mut ms);
        let rep = last.expect("at least one trial");
        let edges = rep.graph.num_edges();
        let eps = edges as f64 / (wall / 1e3).max(1e-9);
        let peak_max = rep.shard_stats.iter().map(|st| st.peak_resident).max().unwrap_or(0);
        let dups: u64 = rep.shard_stats.iter().map(|st| st.duplicates_dropped).sum();
        let batches: u64 = rep.shard_stats.iter().map(|st| st.batches).sum();
        println!(
            "{:>4} {:>8} {:>10.2} {:>14.0} {:>14} {:>12}",
            s, edges, wall, eps, peak_max, dups
        );
        let per_shard: Vec<String> =
            rep.shard_stats.iter().map(|st| shard_stats_obj(st).render()).collect();
        rows.push(
            JsonObj::new()
                .uint("shards", s as u64)
                .uint("workers", rep.workers as u64)
                .uint("edges", edges as u64)
                .float("wall_ms", wall)
                .float("edges_per_sec", eps)
                .uint("batches_total", batches)
                .uint("duplicates_dropped", dups)
                .uint("peak_resident_max", peak_max as u64)
                .arr("per_shard", per_shard)
                .render(),
        );
    }
    section(
        "shard_sweep",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("trials", trials),
        rows,
    )
}

/// Forced-spill sweep of the binary sink: same model, zero in-memory
/// budget, S ∈ {2, 4, 8} — every shard that finishes ahead of the file
/// frontier detours through a spill file, so the sweep measures what the
/// out-of-order/spill path costs against the in-order collect baseline.
/// Returns the JSON rows for `BENCH_quilt.json`.
fn spill_sweep() -> String {
    use magquilt::graph::BinaryFileSink;
    let (d, shard_counts, trials): (u32, &[usize], u64) =
        if fast() { (12, &[4], 2) } else { (15, &[2, 4, 8], 3) };
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    let dir = std::env::temp_dir().join("magquilt_bench_spill");
    std::fs::create_dir_all(&dir).unwrap();
    println!("\n# bench: forced-spill binary sink sweep (theta1, d={d}, n=2^{d}, budget 0)");
    println!(
        "{:>4} {:>10} {:>10} {:>14} {:>12} {:>14}",
        "S", "edges", "wall_ms", "deferred", "spilled", "spill_bytes"
    );
    let mut rows = Vec::new();
    for &s in shard_counts {
        let coord = Coordinator::new().shards(s);
        let path = dir.join(format!("spill_{s}.bin"));
        let mut ms = Vec::new();
        let mut last = None;
        for t in 0..trials {
            let sink = BinaryFileSink::create(&path).spill_dir(&dir).spill_budget(0);
            let start = Instant::now();
            let (written, stats) = coord
                .sample_quilt_with_sink(&params, t, sink)
                .expect("binary sink bench run failed");
            ms.push(start.elapsed().as_secs_f64() * 1e3);
            last = Some((written, stats));
        }
        let wall = median(&mut ms);
        let (written, stats) = last.expect("at least one trial");
        let sp = stats.spill;
        println!(
            "{:>4} {:>10} {:>10.2} {:>14} {:>12} {:>14}",
            s, written, wall, sp.deferred_shards, sp.spilled_shards, sp.spill_bytes
        );
        rows.push(
            JsonObj::new()
                .uint("shards", s as u64)
                .uint("workers", stats.workers as u64)
                .uint("edges", written)
                .float("wall_ms", wall)
                .obj("spill", spill_obj(&sp))
                .render(),
        );
        let _ = std::fs::remove_file(&path);
    }
    section(
        "spill_sweep",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("trials", trials)
            .uint("spill_budget", 0),
        rows,
    )
}

/// Setup-pipeline sweep over setup-thread counts: per-phase wall-clock
/// for chunked attribute sampling, the prefix-sum partition build, the
/// sharded trie build + merge, and the conditioned product-DAG build.
/// The outputs are bit-for-bit identical across thread counts (asserted
/// by the test suite); this sweep measures where the leader's prologue
/// time goes as threads scale. Returns the JSON rows for
/// `BENCH_quilt.json`.
fn setup_sweep() -> String {
    let (d, thread_counts, trials): (u32, &[usize], u64) =
        if fast() { (13, &[1, 4], 2) } else { (16, &[1, 2, 4, 8], 3) };
    let n = 1usize << d;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
    println!("\n# bench: setup pipeline sweep (theta1, d={d}, n=2^{d}, chunked attrs)");
    println!(
        "{:>8} {:>10} {:>13} {:>10} {:>13} {:>10} {:>10}",
        "threads", "attrs_ms", "partition_ms", "trie_ms", "trie_merge_ms", "dag_ms", "total_ms"
    );
    let mut rows = Vec::new();
    for &t in thread_counts {
        let mut attrs_ms = Vec::new();
        let mut partition_ms = Vec::new();
        let mut trie_ms = Vec::new();
        let mut trie_merge_ms = Vec::new();
        let mut dag_ms = Vec::new();
        let mut pair_nodes = 0usize;
        for trial in 0..trials {
            let start = Instant::now();
            let attrs = AttributeAssignment::sample_chunked(&params, &Rng::new(trial), t);
            attrs_ms.push(start.elapsed().as_secs_f64() * 1e3);

            let start = Instant::now();
            let mut p = Partition::build_parallel(attrs.configs(), t);
            partition_ms.push(start.elapsed().as_secs_f64() * 1e3);

            let start = Instant::now();
            p.build_tries_parallel(d as usize, t);
            trie_ms.push(start.elapsed().as_secs_f64() * 1e3);
            trie_merge_ms.push(p.trie_merge_ms());

            let start = Instant::now();
            let cond = p.conditioned_sampler_threaded(params.thetas(), t);
            dag_ms.push(start.elapsed().as_secs_f64() * 1e3);
            pair_nodes = cond.num_pair_nodes();
        }
        let (a, pm, tm, tmm, dm) = (
            median(&mut attrs_ms),
            median(&mut partition_ms),
            median(&mut trie_ms),
            median(&mut trie_merge_ms),
            median(&mut dag_ms),
        );
        println!(
            "{:>8} {:>10.2} {:>13.2} {:>10.2} {:>13.2} {:>10.2} {:>10.2}",
            t,
            a,
            pm,
            tm,
            tmm,
            dm,
            a + pm + tm + dm
        );
        rows.push(
            JsonObj::new()
                .uint("setup_threads", t as u64)
                .float("attrs_ms", a)
                .float("partition_ms", pm)
                .float("trie_ms", tm)
                .float("trie_merge_ms", tmm)
                .float("dag_ms", dm)
                .float("total_ms", a + pm + tm + dm)
                .uint("pair_nodes", pair_nodes as u64)
                .render(),
        );
    }
    section(
        "setup_sweep",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("trials", trials)
            .text("attr_mode", "chunked"),
        rows,
    )
}

/// Distributed-runtime sweep: the same model and seed split across
/// W ∈ {1, 2, 4} workers (run concurrently in-process — each worker is a
/// pure function of the plan, so threads measure the same partitioned
/// work the per-host processes do) plus the deterministic segment merge.
/// The output is bit-for-bit the single-process file (asserted by the
/// test suite); this sweep measures what the partition + merge cost.
/// Returns the JSON rows for `BENCH_quilt.json`.
fn dist_sweep() -> String {
    let (d, worker_counts, shards, trials): (u32, &[usize], usize, u64) =
        if fast() { (12, &[1, 2], 8, 2) } else { (15, &[1, 2, 4], 16, 3) };
    let mut model = ModelSpec::default_spec();
    model.log2_nodes = d;
    model.attributes = d;
    let dir = std::env::temp_dir().join("magquilt_bench_dist");
    println!("\n# bench: distributed runtime sweep (theta1, d={d}, n=2^{d}, S={shards})");
    println!(
        "{:>3} {:>10} {:>12} {:>10} {:>10} {:>9} {:>12}",
        "W", "edges", "workers_ms", "merge_ms", "total_ms", "ovf_runs", "ovf_edges"
    );
    let mut rows = Vec::new();
    for &w in worker_counts {
        let mut run = RunSpec::default_spec();
        run.shards = shards;
        // Bound per-worker thread pools so W workers do not oversubscribe.
        run.workers = 2;
        let mut workers_ms = Vec::new();
        let mut merge_ms = Vec::new();
        let mut last = None;
        for t in 0..trials {
            run.seed = t;
            let plan = ShardPlan::new(&model, &run, w).expect("bench plan");
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir).unwrap();
            let start = Instant::now();
            std::thread::scope(|scope| {
                let plan = &plan;
                let dir = &dir;
                let handles: Vec<_> = (0..plan.num_workers())
                    .map(|i| scope.spawn(move || dist::run_worker(plan, i, dir).unwrap()))
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            });
            workers_ms.push(start.elapsed().as_secs_f64() * 1e3);
            let out = std::env::temp_dir().join("magquilt_bench_dist_merged.bin");
            let start = Instant::now();
            let report = dist::merge_segments(&dir, &plan, &out, true).expect("bench merge");
            merge_ms.push(start.elapsed().as_secs_f64() * 1e3);
            let _ = std::fs::remove_file(&out);
            last = Some(report);
        }
        let (wm, mm) = (median(&mut workers_ms), median(&mut merge_ms));
        let report = last.expect("at least one trial");
        let ovf_edges: usize = report.shards.iter().map(|s| s.overflow_edges).sum();
        println!(
            "{:>3} {:>10} {:>12.2} {:>10.2} {:>10.2} {:>9} {:>12}",
            w,
            report.total_edges,
            wm,
            mm,
            wm + mm,
            report.overflow_runs(),
            ovf_edges
        );
        rows.push(
            JsonObj::new()
                .uint("dist_workers", w as u64)
                .uint("shards", shards as u64)
                .uint("edges", report.total_edges)
                .float("workers_ms", wm)
                .float("merge_ms", mm)
                .float("total_ms", wm + mm)
                .uint("overflow_runs", report.overflow_runs() as u64)
                .uint("overflow_edges", ovf_edges as u64)
                .uint("cross_worker_duplicates", report.duplicates_dropped())
                .render(),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    section(
        "dist_sweep",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("shards", shards as u64)
            .uint("trials", trials),
        rows,
    )
}

/// Segment-merge sweep over merge-thread counts: one fixed segment
/// directory (W workers run once), merged with T ∈ {1, 2, 4, 8} merge
/// threads. The merged file is byte-identical for every T (asserted by
/// the test suite), so the sweep isolates the merge wall-clock — the
/// per-shard validate + fold + dedup that the worker threads parallelize.
/// Returns the JSON rows for `BENCH_quilt.json`.
fn merge_sweep() -> String {
    let (d, shards, workers, thread_counts, trials): (u32, usize, usize, &[usize], u64) =
        if fast() { (12, 8, 2, &[1, 2], 2) } else { (15, 16, 4, &[1, 2, 4, 8], 3) };
    let mut model = ModelSpec::default_spec();
    model.log2_nodes = d;
    model.attributes = d;
    let mut run = RunSpec::default_spec();
    run.shards = shards;
    // Bound per-worker thread pools so the one-off segment build does not
    // oversubscribe; the merge timing below never samples.
    run.workers = 2;
    let plan = ShardPlan::new(&model, &run, workers).expect("bench plan");
    let dir = std::env::temp_dir().join("magquilt_bench_merge");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::thread::scope(|scope| {
        let plan = &plan;
        let dir = &dir;
        let handles: Vec<_> = (0..plan.num_workers())
            .map(|i| scope.spawn(move || dist::run_worker(plan, i, dir).unwrap()))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    println!(
        "\n# bench: segment merge sweep (theta1, d={d}, n=2^{d}, W={workers}, S={shards})"
    );
    println!(
        "{:>3} {:>10} {:>10} {:>14} {:>10} {:>9}",
        "T", "edges", "merge_ms", "edges/s", "deferred", "spilled"
    );
    let mut rows = Vec::new();
    for &t in thread_counts {
        let out = std::env::temp_dir().join(format!("magquilt_bench_merge_t{t}.bin"));
        let mut ms = Vec::new();
        let mut last = None;
        for _ in 0..trials {
            let opts = dist::MergeOptions { merge_threads: t, ..Default::default() };
            let report =
                dist::merge_segments_with(&dir, &plan, &out, &opts).expect("bench merge");
            ms.push(report.merge_ms);
            last = Some(report);
        }
        let _ = std::fs::remove_file(&out);
        let wall = median(&mut ms);
        let report = last.expect("at least one trial");
        let eps = report.total_edges as f64 / (wall / 1e3).max(1e-9);
        println!(
            "{:>3} {:>10} {:>10.2} {:>14.0} {:>10} {:>9}",
            t, report.total_edges, wall, eps, report.deferred_shards, report.spilled_shards
        );
        rows.push(
            JsonObj::new()
                .uint("merge_threads", t as u64)
                .uint("resolved_threads", report.merge_threads as u64)
                .uint("edges", report.total_edges)
                .float("merge_ms", wall)
                .float("edges_per_sec", eps)
                .uint("deferred_shards", report.deferred_shards as u64)
                .uint("spilled_shards", report.spilled_shards as u64)
                .uint("overflow_runs", report.overflow_runs() as u64)
                .uint("cross_worker_duplicates", report.duplicates_dropped())
                .render(),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    section(
        "merge_sweep",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .uint("d", d as u64)
            .uint("workers", workers as u64)
            .uint("shards", shards as u64)
            .uint("trials", trials),
        rows,
    )
}

/// Setup-reuse sweep: the same run end to end with fresh setup vs
/// hydrated from a saved `MAGQART1` setup artifact (load + rebuild of
/// the derived state + sampling). The outputs are bit-for-bit identical
/// (asserted by the test suite); the sweep prices what `--artifact`
/// saves per run and what the one-time build + save costs. Returns the
/// JSON rows for `BENCH_quilt.json`.
fn setup_reuse_sweep() -> String {
    let (ds, trials): (&[u32], u64) = if fast() { (&[12], 2) } else { (&[14, 16], 3) };
    let dir = std::env::temp_dir().join("magquilt_bench_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    println!("\n# bench: setup reuse sweep (theta1, fresh vs artifact-hydrated quilt run)");
    println!(
        "{:>6} {:>10} {:>12} {:>10} {:>9} {:>12} {:>9} {:>12}",
        "log2n", "fresh_ms", "build_ms", "save_ms", "load_ms", "hydrated_ms", "reuse", "bytes"
    );
    let mut rows = Vec::new();
    for &d in ds {
        let mut model = ModelSpec::default_spec();
        model.log2_nodes = d;
        model.attributes = d;
        let params = MagmParams::homogeneous(
            Initiator::new(model.theta),
            model.mu,
            1usize << d,
            model.attributes,
        );
        let coord = Coordinator::new();
        let path = dir.join(format!("setup_{d}.art"));
        let mut fresh_ms = Vec::new();
        let mut build_ms = Vec::new();
        let mut save_ms = Vec::new();
        let mut load_ms = Vec::new();
        let mut hydrated_ms = Vec::new();
        let mut bytes = 0u64;
        for t in 0..trials {
            let start = Instant::now();
            let fresh = coord.sample_quilt(&params, t);
            fresh_ms.push(start.elapsed().as_secs_f64() * 1e3);

            let start = Instant::now();
            let artifact =
                coord.build_setup(&model, t, SamplerKind::Quilt).expect("bench setup build");
            build_ms.push(start.elapsed().as_secs_f64() * 1e3);

            let start = Instant::now();
            artifact.save(&path).expect("bench artifact save");
            save_ms.push(start.elapsed().as_secs_f64() * 1e3);
            bytes = std::fs::metadata(&path).expect("bench artifact stat").len();

            let start = Instant::now();
            let loaded = SetupArtifact::load(&path).expect("bench artifact load");
            let lm = start.elapsed().as_secs_f64() * 1e3;
            load_ms.push(lm);

            let start = Instant::now();
            let hydrated =
                coord.sample_with_artifact(loaded, lm).expect("bench hydrated run");
            hydrated_ms.push(start.elapsed().as_secs_f64() * 1e3);
            // The full byte-identity is asserted by the test suite; keep
            // the cheap invariant hot in the bench too.
            assert_eq!(fresh.graph.num_edges(), hydrated.graph.num_edges());
        }
        let _ = std::fs::remove_file(&path);
        let (f, b, s, l, h) = (
            median(&mut fresh_ms),
            median(&mut build_ms),
            median(&mut save_ms),
            median(&mut load_ms),
            median(&mut hydrated_ms),
        );
        let reuse = f / h.max(1e-9);
        println!(
            "{:>6} {:>10.2} {:>12.2} {:>10.2} {:>9.2} {:>12.2} {:>8.2}x {:>12}",
            d, f, b, s, l, h, reuse, bytes
        );
        rows.push(
            JsonObj::new()
                .uint("log2_nodes", d as u64)
                .float("fresh_ms", f)
                .float("build_ms", b)
                .float("save_ms", s)
                .float("load_ms", l)
                .float("hydrated_ms", h)
                .float("setup_reuse", reuse)
                .uint("artifact_bytes", bytes)
                .render(),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    section(
        "setup_reuse",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .text("sampler", "quilt")
            .uint("trials", trials),
        rows,
    )
}

/// Trace-overhead sweep: the identical coordinator run with telemetry
/// off (the default) and on (an in-memory `TraceHandle`). The sampled
/// graphs are identical either way — the trace-sink lint keeps the
/// telemetry write-only — so the `trace_overhead` column prices exactly
/// what turning tracing on costs: pay for what you use, nothing when it
/// is off. Returns the JSON rows for `BENCH_quilt.json`.
fn trace_overhead_sweep() -> String {
    let (ds, trials): (&[u32], u64) = if fast() { (&[12], 2) } else { (&[14, 16], 3) };
    let dir = std::env::temp_dir().join("magquilt_bench_trace");
    std::fs::create_dir_all(&dir).unwrap();
    println!("\n# bench: trace overhead sweep (theta1, untraced vs traced coordinator run)");
    println!(
        "{:>6} {:>10} {:>12} {:>11} {:>15} {:>8}",
        "log2n", "edges", "untraced_ms", "traced_ms", "trace_overhead", "events"
    );
    let mut rows = Vec::new();
    for &d in ds {
        let n = 1usize << d;
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, n, d);
        let mut untraced_ms = Vec::new();
        let mut traced_ms = Vec::new();
        let mut edges = 0usize;
        let mut events = 0usize;
        for t in 0..trials {
            let start = Instant::now();
            let plain = Coordinator::new().sample_quilt(&params, t);
            untraced_ms.push(start.elapsed().as_secs_f64() * 1e3);

            let trace = TraceHandle::new("bench", "sample", None);
            let coord = Coordinator::new().trace(trace.clone());
            let start = Instant::now();
            let traced = coord.sample_quilt(&params, t);
            traced_ms.push(start.elapsed().as_secs_f64() * 1e3);
            // Full byte-identity is asserted by the test suite; keep the
            // cheap invariant hot in the bench too.
            assert_eq!(plain.graph.num_edges(), traced.graph.num_edges());
            edges = plain.graph.num_edges();

            let path = dir.join(format!("trace_{d}.jsonl"));
            trace.write_to(&path).expect("bench trace write");
            let text = std::fs::read_to_string(&path).expect("bench trace read");
            events = text.lines().count().saturating_sub(1);
        }
        let (u, tr) = (median(&mut untraced_ms), median(&mut traced_ms));
        let overhead = tr - u;
        println!(
            "{:>6} {:>10} {:>12.2} {:>11.2} {:>15.3} {:>8}",
            d, edges, u, tr, overhead, events
        );
        rows.push(
            JsonObj::new()
                .uint("log2_nodes", d as u64)
                .uint("edges", edges as u64)
                .float("untraced_ms", u)
                .float("traced_ms", tr)
                .float("trace_overhead", overhead)
                .uint("trace_events", events as u64)
                .render(),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    section(
        "trace_overhead",
        JsonObj::new()
            .text("theta", "theta1")
            .float("mu", 0.5)
            .text("sampler", "quilt")
            .uint("trials", trials),
        rows,
    )
}

fn main() {
    let (d_max, naive_max, trials) = if fast() { (12, 9, 2) } else { (17, 11, 3) };
    println!("# bench: sampling (paper Fig. 10/11) — trials={trials}");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "theta", "log2n", "quilt_ms", "hybrid_ms", "coord_ms", "naive_ms", "quilt_us/edge", "speedup"
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in (8..=d_max).step_by(2) {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);

            let mut quilt_ms = Vec::new();
            let mut edges = 0usize;
            for t in 0..trials {
                let start = Instant::now();
                let g = QuiltSampler::new(params.clone()).seed(t as u64).sample();
                quilt_ms.push(start.elapsed().as_secs_f64() * 1e3);
                edges = g.num_edges();
            }
            let quilt = median(&mut quilt_ms);

            let mut hybrid_ms = Vec::new();
            for t in 0..trials {
                let start = Instant::now();
                let _ = HybridSampler::new(params.clone()).seed(t as u64).sample();
                hybrid_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            let hybrid = median(&mut hybrid_ms);

            let mut coord_ms = Vec::new();
            let coord = Coordinator::new();
            for t in 0..trials {
                let start = Instant::now();
                let _ = coord.sample_quilt(&params, t as u64);
                coord_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            let coordinated = median(&mut coord_ms);

            let naive = if d <= naive_max {
                let mut ms = Vec::new();
                for t in 0..trials {
                    let mut rng = Rng::new(t as u64);
                    let attrs = AttributeAssignment::sample(&params, &mut rng);
                    let start = Instant::now();
                    let _ = naive_sample(&params, &attrs, &mut rng);
                    ms.push(start.elapsed().as_secs_f64() * 1e3);
                }
                Some(median(&mut ms))
            } else {
                None
            };

            println!(
                "{:>8} {:>6} {:>12.2} {:>12.2} {:>12.2} {:>12} {:>14.3} {:>14}",
                name,
                d,
                quilt,
                hybrid,
                coordinated,
                naive.map_or("-".into(), |v| format!("{v:.2}")),
                quilt * 1e3 / edges.max(1) as f64,
                naive.map_or("-".into(), |v| format!("{:.1}x", v / quilt)),
            );
        }
    }
    let piece_rows = piece_mode_sweep();
    let shard_rows = shard_sweep();
    let spill_rows = spill_sweep();
    let setup_rows = setup_sweep();
    let dist_rows = dist_sweep();
    let merge_rows = merge_sweep();
    let reuse_rows = setup_reuse_sweep();
    let trace_rows = trace_overhead_sweep();
    let sections = [
        piece_rows, shard_rows, spill_rows, setup_rows, dist_rows, merge_rows, reuse_rows,
        trace_rows,
    ]
    .join(",\n");
    let json = format!("{{\n  \"bench\": \"quilt\",\n{sections}\n}}\n");
    match std::fs::write("BENCH_quilt.json", &json) {
        Ok(()) => println!("wrote BENCH_quilt.json"),
        Err(e) => eprintln!("could not write BENCH_quilt.json: {e}"),
    }
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}
