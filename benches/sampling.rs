//! End-to-end sampling bench — regenerates the series behind paper
//! Figures 10 and 11 (quilting vs naive runtime, and per-edge cost).
//!
//! `MAGQUILT_BENCH_FAST=1` shrinks the sweep for smoke runs.

use std::time::Instant;

use magquilt::coordinator::Coordinator;
use magquilt::kpgm::Initiator;
use magquilt::magm::{naive_sample, AttributeAssignment, MagmParams};
use magquilt::quilt::{HybridSampler, QuiltSampler};
use magquilt::rng::Rng;

fn fast() -> bool {
    std::env::var("MAGQUILT_BENCH_FAST").is_ok()
}

fn main() {
    let (d_max, naive_max, trials) = if fast() { (12, 9, 2) } else { (17, 11, 3) };
    println!("# bench: sampling (paper Fig. 10/11) — trials={trials}");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "theta", "log2n", "quilt_ms", "hybrid_ms", "coord_ms", "naive_ms", "quilt_us/edge", "speedup"
    );
    for (name, theta) in [("theta1", Initiator::THETA1), ("theta2", Initiator::THETA2)] {
        for d in (8..=d_max).step_by(2) {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(theta, 0.5, n, d);

            let mut quilt_ms = Vec::new();
            let mut edges = 0usize;
            for t in 0..trials {
                let start = Instant::now();
                let g = QuiltSampler::new(params.clone()).seed(t as u64).sample();
                quilt_ms.push(start.elapsed().as_secs_f64() * 1e3);
                edges = g.num_edges();
            }
            let quilt = median(&mut quilt_ms);

            let mut hybrid_ms = Vec::new();
            for t in 0..trials {
                let start = Instant::now();
                let _ = HybridSampler::new(params.clone()).seed(t as u64).sample();
                hybrid_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            let hybrid = median(&mut hybrid_ms);

            let mut coord_ms = Vec::new();
            let coord = Coordinator::new();
            for t in 0..trials {
                let start = Instant::now();
                let _ = coord.sample_quilt(&params, t as u64);
                coord_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            let coordinated = median(&mut coord_ms);

            let naive = if d <= naive_max {
                let mut ms = Vec::new();
                for t in 0..trials {
                    let mut rng = Rng::new(t as u64);
                    let attrs = AttributeAssignment::sample(&params, &mut rng);
                    let start = Instant::now();
                    let _ = naive_sample(&params, &attrs, &mut rng);
                    ms.push(start.elapsed().as_secs_f64() * 1e3);
                }
                Some(median(&mut ms))
            } else {
                None
            };

            println!(
                "{:>8} {:>6} {:>12.2} {:>12.2} {:>12.2} {:>12} {:>14.3} {:>14}",
                name,
                d,
                quilt,
                hybrid,
                coordinated,
                naive.map_or("-".into(), |v| format!("{v:.2}")),
                quilt * 1e3 / edges.max(1) as f64,
                naive.map_or("-".into(), |v| format!("{:.1}x", v / quilt)),
            );
        }
    }
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}
