//! Partition benchmark — the series behind paper Figures 5 and 6: the
//! size B of the minimal partition and the cost of building it, plus the
//! conditioned-piece setup costs (per-set prefix tries and the shared
//! product DAG with its per-piece restricted masses).

use std::time::Instant;

use magquilt::kpgm::Initiator;
use magquilt::magm::{AttributeAssignment, MagmParams};
use magquilt::quilt::Partition;
use magquilt::rng::Rng;

fn main() {
    let fast = std::env::var("MAGQUILT_BENCH_FAST").is_ok();
    let d_max = if fast { 14 } else { 20 };
    println!("# bench: partition + conditioned-piece setup (paper Fig. 5/6)");
    println!(
        "{:>5} {:>10} {:>5} {:>6} {:>12} {:>12} {:>10} {:>10} {:>12}",
        "mu", "n", "d", "B", "build_ms", "ns/node", "trie_ms", "dag_ms", "pair_nodes"
    );
    for &mu in &[0.5, 0.7, 0.9] {
        for d in (8..=d_max).step_by(4) {
            let n = 1usize << d;
            let params = MagmParams::homogeneous(Initiator::THETA1, mu, n, d);
            let mut rng = Rng::new(d as u64);
            let attrs = AttributeAssignment::sample(&params, &mut rng);
            let start = Instant::now();
            let mut p = Partition::build(attrs.configs());
            let ms = start.elapsed().as_secs_f64() * 1e3;

            let start = Instant::now();
            p.build_tries(d as usize);
            let trie_ms = start.elapsed().as_secs_f64() * 1e3;

            let start = Instant::now();
            let cond = p.conditioned_sampler(params.thetas());
            let dag_ms = start.elapsed().as_secs_f64() * 1e3;

            println!(
                "{:>5.2} {:>10} {:>5} {:>6} {:>12.2} {:>12.1} {:>10.2} {:>10.2} {:>12}",
                mu,
                n,
                d,
                p.size(),
                ms,
                ms * 1e6 / n as f64,
                trie_ms,
                dag_ms,
                cond.num_pair_nodes()
            );
        }
    }
}
