//! Runtime bench: AOT XLA kernel throughput vs the pure-Rust scalar path
//! for the edge-probability block — the L1/L2 hot-spot measured from L3.
//!
//! Needs `make artifacts`; exits gracefully if they are missing.

use std::time::Instant;

use magquilt::kpgm::Initiator;
use magquilt::magm::{AttributeAssignment, MagmParams};
use magquilt::rng::Rng;
use magquilt::runtime::{MagmKernels, XlaRuntime};

fn main() {
    let runtime = match XlaRuntime::load_default() {
        Ok(r) => r,
        Err(e) => {
            println!("# bench: xla runtime SKIPPED ({e})");
            return;
        }
    };
    let fast = std::env::var("MAGQUILT_BENCH_FAST").is_ok();
    let reps = if fast { 3 } else { 10 };
    println!("# bench: XLA edge_prob kernels vs pure-Rust (block = manifest shape)");

    for d in [8u32, 16, 24, 32] {
        let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 4096, d);
        let mut rng = Rng::new(3);
        let attrs = AttributeAssignment::sample(&params, &mut rng);
        let kernels = MagmKernels::new(&runtime, params.thetas());
        let bm = runtime.manifest().bm;
        let bn = runtime.manifest().bn;
        let src: Vec<u32> = (0..bm as u32).collect();
        let dst: Vec<u32> = (bm as u32..(bm + bn) as u32).collect();

        // warmup + timed XLA block
        let _ = kernels.edge_prob_block(&attrs, &src, &dst).unwrap();
        let start = Instant::now();
        for _ in 0..reps {
            let _ = kernels.edge_prob_block(&attrs, &src, &dst).unwrap();
        }
        let xla_ms = start.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let cells = (bm * bn) as f64;

        // pure-Rust scalar evaluation of the same block
        let start = Instant::now();
        let mut sink = 0.0f64;
        for &i in &src {
            for &j in &dst {
                sink += magquilt::magm::edge_probability(&params, &attrs, i, j);
            }
        }
        let rust_ms = start.elapsed().as_secs_f64() * 1e3;

        println!(
            "d={d:>2}: xla {xla_ms:>8.2} ms ({:.1} ns/cell) | rust scalar {rust_ms:>8.2} ms ({:.1} ns/cell) | xla speedup {:.1}x (sink {sink:.1})",
            xla_ms * 1e6 / cells,
            rust_ms * 1e6 / cells,
            rust_ms / xla_ms
        );
    }

    // pairs kernel
    let d = 16u32;
    let params = MagmParams::homogeneous(Initiator::THETA1, 0.5, 1 << 14, d);
    let mut rng = Rng::new(4);
    let attrs = AttributeAssignment::sample(&params, &mut rng);
    let kernels = MagmKernels::new(&runtime, params.thetas());
    let bp = runtime.manifest().bp;
    let pairs: Vec<(u32, u32)> =
        (0..bp).map(|_| (rng.below(1 << 14) as u32, rng.below(1 << 14) as u32)).collect();
    let _ = kernels.edge_prob_pairs(&attrs, &pairs).unwrap();
    let start = Instant::now();
    for _ in 0..reps {
        let _ = kernels.edge_prob_pairs(&attrs, &pairs).unwrap();
    }
    let ms = start.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!("pairs kernel d={d}: {ms:.2} ms for {bp} pairs ({:.1} ns/pair)", ms * 1e6 / bp as f64);
}
